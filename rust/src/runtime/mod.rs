//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute them
//! from the Rust hot path.
//!
//! Interchange is HLO *text* (see `python/compile/aot.py`): jax >= 0.5
//! emits serialized protos with 64-bit instruction ids that this image's
//! xla_extension 0.5.1 rejects; `HloModuleProto::from_text_file` reassigns
//! ids and round-trips cleanly.
//!
//! PJRT handles are raw C pointers (`!Send`/`!Sync`), so a runtime is
//! thread-local by construction: the coordinator's worker pool builds one
//! [`XlaRuntime`] per worker thread.

mod artifact;
// The real `xla` crate (PJRT FFI bindings) is unavailable in this offline
// build; an API-compatible stub keeps the runtime compiling and fails at
// executable-load time with a clear message. Swap this import for the real
// crate to re-enable the AOT path (DESIGN.md §2).
mod xla_stub;
use xla_stub as xla;

pub use artifact::{Artifact, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// Inputs of one AOT `mac_forward` execution (one fixed-size batch).
#[derive(Debug, Clone)]
pub struct MacBatch {
    /// Stored operand bits, row-major (batch, 4), MSB first, values {0,1}.
    pub a_bits: Vec<f32>,
    /// DAC codes (batch,), values 0..=15.
    pub b_code: Vec<f32>,
    /// Forward body bias (V).
    pub v_bulk: f32,
    /// DAC mode flag: 0 = linear Eq. 7, 1 = sqrt Eq. 8.
    pub dac_mode: f32,
    /// WL pulse width at the sampling instant (s).
    pub t_sample: f32,
    /// VTH mismatch deviates (V), row-major (batch, 4).
    pub dvth: Vec<f32>,
    /// Relative beta mismatch deviates, row-major (batch, 4).
    pub dbeta: Vec<f32>,
}

impl MacBatch {
    /// Batch with nominal devices, ready to be filled.
    pub fn nominal(batch: usize, v_bulk: f32, dac_mode: f32, t_sample: f32) -> Self {
        Self {
            a_bits: vec![0.0; batch * 4],
            b_code: vec![0.0; batch],
            v_bulk,
            dac_mode,
            t_sample,
            dvth: vec![0.0; batch * 4],
            dbeta: vec![0.0; batch * 4],
        }
    }

    /// Number of rows in the batch.
    pub fn len(&self) -> usize {
        self.b_code.len()
    }

    /// True for a zero-row batch.
    pub fn is_empty(&self) -> bool {
        self.b_code.is_empty()
    }

    /// Set row `i` to operands (a, b) with mismatch deviates.
    pub fn set_row(&mut self, i: usize, a: u8, b: u8, dvth: [f32; 4], dbeta: [f32; 4]) {
        assert!(a < 16 && b < 16);
        for k in 0..4 {
            self.a_bits[i * 4 + k] = f32::from(a >> (3 - k) & 1);
            self.dvth[i * 4 + k] = dvth[k];
            self.dbeta[i * 4 + k] = dbeta[k];
        }
        self.b_code[i] = f32::from(b);
    }
}

/// Outputs of one AOT `mac_forward` execution.
#[derive(Debug, Clone)]
pub struct MacBatchOut {
    /// Weighted discharge voltage per row — the paper's V_multiplication.
    pub v_mult: Vec<f32>,
    /// Sampled BLB voltages, row-major (batch, 4).
    pub v_blb: Vec<f32>,
    /// Raw dynamic bitline energy per row (J).
    pub energy: Vec<f32>,
    /// Saturation-exit fault flags per row (0/1).
    pub fault: Vec<f32>,
}

/// A compiled MAC executable for one fixed batch size.
pub struct MacExecutable {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
}

impl MacExecutable {
    /// The fixed batch size this executable was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Execute one batch. `inputs.len()` must equal the compiled batch.
    pub fn run(&self, inputs: &MacBatch) -> Result<MacBatchOut> {
        let b = self.batch;
        anyhow::ensure!(
            inputs.len() == b,
            "batch mismatch: executable compiled for {b}, got {}",
            inputs.len()
        );
        let lit = |v: &[f32], dims: &[i64]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(v).reshape(dims)?)
        };
        let args = [
            lit(&inputs.a_bits, &[b as i64, 4])?,
            lit(&inputs.b_code, &[b as i64])?,
            xla::Literal::scalar(inputs.v_bulk),
            xla::Literal::scalar(inputs.dac_mode),
            xla::Literal::scalar(inputs.t_sample),
            lit(&inputs.dvth, &[b as i64, 4])?,
            lit(&inputs.dbeta, &[b as i64, 4])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(tuple.len() == 4, "expected 4 outputs, got {}", tuple.len());
        let mut it = tuple.into_iter();
        let mut next = || it.next().ok_or_else(|| anyhow::anyhow!("output tuple ended early"));
        let out = MacBatchOut {
            v_mult: next()?.to_vec::<f32>()?,
            v_blb: next()?.to_vec::<f32>()?,
            energy: next()?.to_vec::<f32>()?,
            fault: next()?.to_vec::<f32>()?,
        };
        anyhow::ensure!(out.v_mult.len() == b && out.v_blb.len() == b * 4);
        Ok(out)
    }
}

/// Inputs of one AOT `dot_forward` execution: a (batch, R)-row analog
/// vector-matrix-multiply column (Fig. 7 array as a VMM engine).
#[derive(Debug, Clone)]
pub struct DotBatch {
    /// Stored weight bits, row-major (batch, R, 4), MSB first.
    pub a_bits: Vec<f32>,
    /// Per-row DAC codes (batch, R).
    pub b_code: Vec<f32>,
    /// Forward body bias (V).
    pub v_bulk: f32,
    /// DAC mode flag: 0 = linear Eq. 7, 1 = sqrt Eq. 8.
    pub dac_mode: f32,
    /// WL pulse width (s). Convention: `t_sample / 4` keeps the all-rows
    /// full scale equal to the single-row MAC's (C_bl scales with R).
    pub t_sample: f32,
    /// VTH mismatch deviates (V), row-major (batch, R, 4).
    pub dvth: Vec<f32>,
    /// Relative beta mismatch deviates, row-major (batch, R, 4).
    pub dbeta: Vec<f32>,
    rows: usize,
}

impl DotBatch {
    /// Batch with nominal devices, ready to be filled.
    pub fn nominal(batch: usize, rows: usize, v_bulk: f32, dac_mode: f32, t_sample: f32) -> Self {
        Self {
            a_bits: vec![0.0; batch * rows * 4],
            b_code: vec![0.0; batch * rows],
            v_bulk,
            dac_mode,
            t_sample,
            dvth: vec![0.0; batch * rows * 4],
            dbeta: vec![0.0; batch * rows * 4],
            rows,
        }
    }

    /// Number of batch elements (dot products).
    pub fn len(&self) -> usize {
        self.b_code.len() / self.rows
    }

    /// True for a zero-element batch.
    pub fn is_empty(&self) -> bool {
        self.b_code.is_empty()
    }

    /// Array rows per dot product.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Set row `r` of batch element `i` to weight `a`, code `b`, deviates.
    pub fn set_row(&mut self, i: usize, r: usize, a: u8, b: u8, dvth: [f32; 4], dbeta: [f32; 4]) {
        assert!(a < 16 && b < 16 && r < self.rows);
        let base = (i * self.rows + r) * 4;
        for k in 0..4 {
            self.a_bits[base + k] = f32::from(a >> (3 - k) & 1);
            self.dvth[base + k] = dvth[k];
            self.dbeta[base + k] = dbeta[k];
        }
        self.b_code[i * self.rows + r] = f32::from(b);
    }
}

/// Outputs of one `dot_forward` execution.
#[derive(Debug, Clone)]
pub struct DotBatchOut {
    /// Weighted shared-bitline discharge — analog sum_r(a_r * b_r).
    pub v_dot: Vec<f32>,
    /// Sampled shared-bitline voltages (batch, 4).
    pub v_bl: Vec<f32>,
    /// Raw dynamic bitline energy per element (J).
    pub energy: Vec<f32>,
    /// Saturation-exit fault flags per element (0/1).
    pub fault: Vec<f32>,
}

/// A compiled dot-product executable for one fixed (batch, rows).
pub struct DotExecutable {
    exe: xla::PjRtLoadedExecutable,
    batch: usize,
    rows: usize,
}

impl DotExecutable {
    /// The fixed batch size this executable was compiled for.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// The fixed row count this executable was compiled for.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Execute one batch. Shapes must match the compiled (batch, rows).
    pub fn run(&self, inputs: &DotBatch) -> Result<DotBatchOut> {
        let (b, r) = (self.batch, self.rows);
        anyhow::ensure!(
            inputs.len() == b && inputs.rows() == r,
            "dot batch mismatch: compiled ({b}, {r}), got ({}, {})",
            inputs.len(),
            inputs.rows()
        );
        let (bi, ri) = (b as i64, r as i64);
        let args = [
            xla::Literal::vec1(&inputs.a_bits).reshape(&[bi, ri, 4])?,
            xla::Literal::vec1(&inputs.b_code).reshape(&[bi, ri])?,
            xla::Literal::scalar(inputs.v_bulk),
            xla::Literal::scalar(inputs.dac_mode),
            xla::Literal::scalar(inputs.t_sample),
            xla::Literal::vec1(&inputs.dvth).reshape(&[bi, ri, 4])?,
            xla::Literal::vec1(&inputs.dbeta).reshape(&[bi, ri, 4])?,
        ];
        let result = self.exe.execute::<xla::Literal>(&args)?;
        let tuple = result[0][0].to_literal_sync()?.to_tuple()?;
        anyhow::ensure!(tuple.len() == 4, "expected 4 outputs, got {}", tuple.len());
        let mut it = tuple.into_iter();
        let mut next = || it.next().ok_or_else(|| anyhow::anyhow!("output tuple ended early"));
        let out = DotBatchOut {
            v_dot: next()?.to_vec::<f32>()?,
            v_bl: next()?.to_vec::<f32>()?,
            energy: next()?.to_vec::<f32>()?,
            fault: next()?.to_vec::<f32>()?,
        };
        anyhow::ensure!(out.v_dot.len() == b && out.v_bl.len() == b * 4);
        Ok(out)
    }
}

/// A thread-local PJRT CPU client with a compiled-executable cache.
pub struct XlaRuntime {
    client: xla::PjRtClient,
    artifact_dir: PathBuf,
    manifest: Manifest,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl XlaRuntime {
    /// Open the artifact directory produced by `make artifacts`.
    pub fn open(artifact_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Self { client, artifact_dir: dir, manifest, cache: HashMap::new() })
    }

    /// The loaded artifact manifest.
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// PJRT platform name (e.g. "cpu").
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch from cache) the artifact named `name`.
    fn compile(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let art = self
                .manifest
                .find(name)
                .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
            let path = self.artifact_dir.join(&art.path);
            let proto = xla::HloModuleProto::from_text_file(&path)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Load the MAC executable for `batch` (must be one of the compiled
    /// batch sizes in the manifest).
    pub fn mac_executable(&mut self, batch: usize) -> Result<MacExecutable> {
        let name = format!("mac_b{batch}");
        anyhow::ensure!(
            self.manifest.mac_batches.contains(&batch),
            "no mac artifact for batch {batch}; available: {:?}",
            self.manifest.mac_batches
        );
        // Executables are cheap handles around refcounted C++ objects, but
        // the crate exposes no clone; compile again into a standalone handle.
        let art = self
            .manifest
            .find(&name)
            .ok_or_else(|| anyhow!("manifest lists batch {batch} but has no '{name}' entry"))?;
        let path = self.artifact_dir.join(&art.path);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(MacExecutable { exe, batch })
    }

    /// Load the dot-product executable for `batch` (x `manifest.dot_rows`).
    pub fn dot_executable(&mut self, batch: usize) -> Result<DotExecutable> {
        let rows = self.manifest.dot_rows;
        let name = format!("dot_r{rows}_b{batch}");
        anyhow::ensure!(
            self.manifest.dot_batches.contains(&batch),
            "no dot artifact for batch {batch}; available: {:?}",
            self.manifest.dot_batches
        );
        let art = self
            .manifest
            .find(&name)
            .ok_or_else(|| anyhow!("manifest lists dot batch {batch} but has no '{name}' entry"))?;
        let path = self.artifact_dir.join(&art.path);
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        Ok(DotExecutable { exe, batch, rows })
    }

    /// Largest compiled batch size <= `n`, falling back to the smallest.
    pub fn best_batch(&self, n: usize) -> usize {
        self.manifest
            .mac_batches
            .iter()
            .copied()
            .filter(|&b| b <= n)
            .max()
            .or_else(|| self.manifest.mac_batches.iter().copied().min())
            // lint:allow(D4): Manifest::parse rejects empty mac_batches, so min() is always Some
            .expect("manifest has at least one mac batch")
    }

    /// Run the waveform-trace artifact (Fig. 5/6): returns
    /// (n_points, batch, 4) row-major samples of V_BLB(t).
    pub fn run_trace(&mut self, inputs: &MacBatch, t_total: f32) -> Result<Vec<f32>> {
        let batch = inputs.len();
        let name = format!("trace_b{batch}");
        anyhow::ensure!(
            self.manifest.trace_batches.contains(&batch),
            "no trace artifact for batch {batch}; available: {:?}",
            self.manifest.trace_batches
        );
        let b = batch as i64;
        let args = [
            xla::Literal::vec1(&inputs.a_bits).reshape(&[b, 4])?,
            xla::Literal::vec1(&inputs.b_code).reshape(&[b])?,
            xla::Literal::scalar(inputs.v_bulk),
            xla::Literal::scalar(inputs.dac_mode),
            xla::Literal::scalar(t_total),
            xla::Literal::vec1(&inputs.dvth).reshape(&[b, 4])?,
            xla::Literal::vec1(&inputs.dbeta).reshape(&[b, 4])?,
        ];
        let exe = self.compile(&name)?;
        let result = exe.execute::<xla::Literal>(&args)?;
        let lit = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(lit.to_vec::<f32>()?)
    }
}

/// Locate the artifact directory: `$SMART_ARTIFACTS`, else `./artifacts`,
/// else walking up from the executable (so tests/benches work from any cwd).
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(p) = std::env::var("SMART_ARTIFACTS") {
        return PathBuf::from(p);
    }
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mac_batch_set_row_layout() {
        let mut b = MacBatch::nominal(2, 0.6, 1.0, 0.17e-9);
        b.set_row(0, 0b1010, 7, [1e-3; 4], [0.0; 4]);
        b.set_row(1, 0b0001, 15, [0.0; 4], [0.01; 4]);
        assert_eq!(&b.a_bits[0..4], &[1.0, 0.0, 1.0, 0.0]);
        assert_eq!(&b.a_bits[4..8], &[0.0, 0.0, 0.0, 1.0]);
        assert_eq!(b.b_code, vec![7.0, 15.0]);
        assert_eq!(b.dvth[0], 1e-3);
        assert_eq!(b.dbeta[7], 0.01);
        assert_eq!(b.len(), 2);
    }

    #[test]
    #[should_panic]
    fn set_row_rejects_wide_operands() {
        MacBatch::nominal(1, 0.0, 1.0, 1e-10).set_row(0, 16, 0, [0.0; 4], [0.0; 4]);
    }
}
