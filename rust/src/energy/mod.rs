//! Energy-per-MAC and cycle-time models behind Table 1.
//!
//! The bitline dynamic energy comes from the transient simulation
//! (sum C*VDD*dV, reported by both the native engine and the AOT
//! artifact). Peripheral overheads — DAC, WL driver, sense amp, control,
//! and SMART's dual-VDD body-bias rail — are technology constants fitted
//! to the published anchor rows of Table 1 ([9] 0.9 pJ, [10] 0.523 pJ) and
//! documented in DESIGN.md §6; the *shape* (SMART slightly above AID,
//! below IMAC; SMART fastest) emerges from the circuit, not the fit.

use crate::mac::{Variant, VariantConfig};
use crate::params::Params;

/// Fixed peripheral energy/timing constants (65 nm, fitted — see module doc).
#[derive(Debug, Clone, Copy)]
pub struct EnergyModel {
    /// 4-bit DAC conversion energy at 1 V (J); scales with supply^2.
    pub e_dac: f64,
    /// WL driver load capacitance (F); energy = C * V_WL^2.
    pub c_wl: f64,
    /// Sense-amp + latch energy per op at 1 V (J); scales with supply^2.
    pub e_sense: f64,
    /// Clock/control overhead per op at 1 V (J); scales with supply^2.
    pub e_ctrl: f64,
    /// Dual-VDD body-bias rail overhead (J) — SMART only (charge pumping
    /// the deep n-well and the second supply's distribution).
    pub e_body_rail: f64,
    /// Extra interface energy for the linear-DAC family (J at 1 V): IMAC's
    /// quadratic code interpretation needs an 8-bit-grade readout.
    pub e_iface_linear: f64,
    /// Precharge phase duration (s).
    pub t_precharge: f64,
    /// Sense time constant (s*V): t_sense = k / dV_fullscale — a larger
    /// sampled swing resolves faster.
    pub k_sense: f64,
    /// Interface/digitization time (s) for the sqrt-DAC family; fitted to
    /// the published frequencies ([9] 100 MHz, [10] 200 MHz). SMART
    /// inherits AID's interface circuitry (paper §III).
    pub t_iface_sqrt: f64,
    /// Interface/digitization time (s) for the linear-DAC family.
    pub t_iface_linear: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        Self {
            e_dac: 0.24e-12,
            c_wl: 50e-15,
            e_sense: 0.14e-12,
            e_ctrl: 0.05e-12,
            e_body_rail: 0.16e-12,
            e_iface_linear: 0.15e-12,
            t_precharge: 1.75e-9,
            k_sense: 0.6e-9, // 0.6 ns*V: ~2.2 ns at AID's 0.27 V swing
            t_iface_sqrt: 0.8e-9,
            t_iface_linear: 5.86e-9,
        }
    }
}

/// Energy/timing breakdown for one MAC operation.
#[derive(Debug, Clone, Copy)]
pub struct OpCost {
    /// Total energy per MAC (J).
    pub energy: f64,
    /// Cycle time (s).
    pub t_cycle: f64,
    /// Operating frequency (Hz) — the cycle time's reciprocal.
    pub frequency: f64,
}

impl EnergyModel {
    /// Total energy for one MAC given the simulated raw bitline energy
    /// (J) at the cell supply. Peripheral terms scale with the variant's
    /// peripheral supply squared (CV^2 switching).
    pub fn op_energy(&self, cfg: &VariantConfig, raw_bitline: f64, v_wl_max: f64) -> f64 {
        let s2 = cfg.supply * cfg.supply;
        // precharge restores the discharged charge (same magnitude again);
        // bitlines swing at the variant's cell supply (CV^2 scaling)
        let bitline = 2.0 * raw_bitline * s2;
        let wl = self.c_wl * v_wl_max * v_wl_max;
        let mut fixed = (self.e_dac + self.e_sense + self.e_ctrl) * s2;
        if cfg.variant == Variant::Imac {
            fixed += self.e_iface_linear * s2;
        }
        let rail = if cfg.v_bulk > 0.0 { self.e_body_rail } else { 0.0 };
        bitline + wl + fixed + rail
    }

    /// Cycle time: precharge + WL pulse + swing-dependent sense + interface.
    pub fn op_time(&self, cfg: &VariantConfig, dv_full_scale: f64) -> f64 {
        let t_sense = self.k_sense / dv_full_scale.max(1e-3);
        let t_iface = match cfg.variant {
            Variant::Imac => self.t_iface_linear,
            Variant::Smart | Variant::Aid | Variant::SmartOnImac => self.t_iface_sqrt,
        };
        self.t_precharge + cfg.t_sample + t_sense + t_iface
    }

    /// Full per-op cost for a variant, given its simulated raw bitline
    /// energy and full-scale discharge swing.
    pub fn cost(
        &self,
        cfg: &VariantConfig,
        raw_bitline: f64,
        dv_full_scale: f64,
        v_wl_max: f64,
    ) -> OpCost {
        let t_cycle = self.op_time(cfg, dv_full_scale);
        OpCost {
            energy: self.op_energy(cfg, raw_bitline, v_wl_max),
            t_cycle,
            frequency: 1.0 / t_cycle,
        }
    }
}

/// Literature rows quoted (not simulated) in Table 1 — comparators with no
/// published netlists; carried as constants exactly like the paper does.
#[derive(Debug, Clone, Copy)]
pub struct LiteratureRow {
    /// Citation label as printed in Table 1.
    pub label: &'static str,
    /// Technology node (nm).
    pub tech_nm: u32,
    /// Supply voltage (V).
    pub supply: f64,
    /// Published MAC energy (pJ).
    pub mac_energy_pj: f64,
    /// Published accuracy figure, when the source reports one.
    pub accuracy_std: Option<f64>,
    /// Published frequency, verbatim (some sources quote ranges).
    pub freq_mhz: &'static str,
}

/// Table 1's [14] and [21] rows.
pub const LITERATURE_ROWS: [LiteratureRow; 2] = [
    LiteratureRow {
        label: "[14] (lit.)",
        tech_nm: 65,
        supply: 1.0,
        mac_energy_pj: 1.3,
        accuracy_std: None,
        freq_mhz: "60-125",
    },
    LiteratureRow {
        label: "[21] (lit.)",
        tech_nm: 65,
        supply: 1.2,
        mac_energy_pj: 3.5,
        accuracy_std: None,
        freq_mhz: "2.5",
    },
];

/// Helper: simulated full-scale raw bitline energy + swing for a variant
/// (nominal devices), used by the Table 1 bench and the CLI.
pub fn nominal_cost(params: &Params, variant: Variant, model: &EnergyModel) -> OpCost {
    use crate::mac::NativeMacEngine;
    use crate::montecarlo::McSample;
    let cfg = variant.config(params);
    let engine = NativeMacEngine::new(*params, cfg);
    let r = engine.mac(15, 15, &McSample::nominal());
    let v_wl_max = engine.dac().v_wl(15);
    model.cost(&cfg, r.energy, r.v_mult, v_wl_max)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::Params;

    #[test]
    fn table1_energy_ordering() {
        // Paper Table 1: AID 0.523 < SMART 0.783 < IMAC 0.9 (pJ).
        let p = Params::default();
        let m = EnergyModel::default();
        let aid = nominal_cost(&p, Variant::Aid, &m).energy;
        let smart = nominal_cost(&p, Variant::Smart, &m).energy;
        let imac = nominal_cost(&p, Variant::Imac, &m).energy;
        assert!(aid < smart, "AID {aid} !< SMART {smart}");
        assert!(smart < imac, "SMART {smart} !< IMAC {imac}");
        // ballpark: within ~50% of the published numbers
        assert!((0.35e-12..0.80e-12).contains(&aid), "AID {aid}");
        assert!((0.5e-12..1.2e-12).contains(&smart), "SMART {smart}");
        assert!((0.6e-12..1.4e-12).contains(&imac), "IMAC {imac}");
    }

    #[test]
    fn table1_frequency_ordering() {
        // Paper Table 1: SMART 250 > AID 200 > IMAC 100 (MHz).
        let p = Params::default();
        let m = EnergyModel::default();
        let f = |v| nominal_cost(&p, v, &m).frequency / 1e6;
        let (fs, fa, fi) = (f(Variant::Smart), f(Variant::Aid), f(Variant::Imac));
        assert!(fs > fa && fa > fi, "S={fs} A={fa} I={fi}");
        assert!((180.0..320.0).contains(&fs), "SMART {fs} MHz");
        assert!((150.0..260.0).contains(&fa), "AID {fa} MHz");
        assert!((70.0..140.0).contains(&fi), "IMAC {fi} MHz");
    }

    #[test]
    fn body_rail_only_charged_when_biased() {
        let p = Params::default();
        let m = EnergyModel::default();
        let smart = Variant::Smart.config(&p);
        let aid = Variant::Aid.config(&p);
        let e_s = m.op_energy(&smart, 50e-15, 0.7);
        let e_a = m.op_energy(&aid, 50e-15, 0.7);
        assert!((e_s - e_a - m.e_body_rail).abs() < 1e-18);
    }

    #[test]
    fn bigger_swing_senses_faster() {
        let p = Params::default();
        let m = EnergyModel::default();
        let cfg = Variant::Smart.config(&p);
        assert!(m.op_time(&cfg, 0.5) < m.op_time(&cfg, 0.2));
    }

    #[test]
    fn literature_rows_match_paper() {
        assert_eq!(LITERATURE_ROWS[0].mac_energy_pj, 1.3);
        assert_eq!(LITERATURE_ROWS[1].mac_energy_pj, 3.5);
        assert_eq!(LITERATURE_ROWS[1].freq_mhz, "2.5");
    }
}
