//! Tiling quantized matrix–vector products onto the analog MAC through
//! the block-execution engine (DESIGN.md §10).
//!
//! Each signed product `w_q * x_q` splits into unsigned 4-bit array
//! words ([`super::nibble`]); every word pair is one analog MAC op. Ops
//! are enumerated in canonical nested order — output neuron, input
//! feature, weight word, activation word — and carry a **global item
//! index**, so their mismatch deviates come from
//! [`MismatchSampler::fill_block`]'s per-item counter streams: a pure
//! function of `(seed, item)`, independent of how the op stream is cut
//! into blocks, shards, or threads.
//!
//! Reconstruction is **offset-calibrated**: the digital side subtracts
//! the nominal (mismatch-free) output of the executing kernel for the
//! same operand pair and adds the rounded deviation, in product units,
//! to the exact word product. With mismatch off the measured voltage
//! equals the calibration entry bit for bit, so the noisy forward pass
//! collapses to the exact integer pipeline — the property
//! `tests/nn_infer.rs` pins.

use crate::mac::{NativeMacEngine, SimKernel, TrialBlock};
use crate::montecarlo::{McSample, MismatchSampler};

use super::quant::{nibble, QuantMatrix, QuantVec};

/// Outputs of one tiled matrix–vector product.
#[derive(Debug, Clone, PartialEq)]
pub struct MatvecResult {
    /// Signed integer accumulators per output neuron (code space).
    pub acc: Vec<i64>,
    /// Raw dynamic bitline energy over all ops (J), summed in canonical
    /// op order.
    pub energy: f64,
    /// Saturation-exit faults observed across the ops.
    pub faults: u64,
    /// Analog MAC ops executed (`rows * cols * words^2`).
    pub ops: u64,
}

/// Drives quantized layers through a [`SimKernel`], one reusable
/// [`TrialBlock`] per tiler (zero steady-state allocation).
pub struct Tiler<'a> {
    engine: &'a NativeMacEngine,
    kernel: &'a dyn SimKernel,
    sampler: &'a MismatchSampler,
    /// Nominal kernel output per operand pair (`f32`, the kernels'
    /// output precision) — the offset-calibration table.
    cal: Vec<f32>,
    full_scale: f64,
    block_len: usize,
    block: TrialBlock,
}

impl<'a> Tiler<'a> {
    /// The offset-calibration table for `engine`: its nominal output for
    /// all 256 operand pairs, in the same `f32` precision the kernels
    /// emit (scalar and block kernels are bit-identical, so the table is
    /// kernel-independent). 256 transient simulations — compute it once
    /// per engine and share it across shard tilers
    /// ([`Tiler::with_calibration`]).
    pub fn calibrate(engine: &NativeMacEngine) -> Vec<f32> {
        let nominal = McSample::nominal();
        let mut cal = Vec::with_capacity(256);
        for a in 0..16u8 {
            for b in 0..16u8 {
                cal.push(engine.mac(a, b, &nominal).v_mult as f32);
            }
        }
        cal
    }

    /// Tiler over `engine` executing at most `block_len` ops per
    /// [`TrialBlock`], computing its own calibration table (convenience
    /// for one-off tilers; campaigns share one table via
    /// [`Tiler::with_calibration`]).
    pub fn new(
        engine: &'a NativeMacEngine,
        kernel: &'a dyn SimKernel,
        sampler: &'a MismatchSampler,
        block_len: usize,
    ) -> Self {
        let cal = Self::calibrate(engine);
        Self::with_calibration(engine, kernel, sampler, block_len, cal)
    }

    /// Tiler reusing a precomputed [`Tiler::calibrate`] table for the
    /// same engine configuration.
    pub fn with_calibration(
        engine: &'a NativeMacEngine,
        kernel: &'a dyn SimKernel,
        sampler: &'a MismatchSampler,
        block_len: usize,
        cal: Vec<f32>,
    ) -> Self {
        assert!(block_len >= 1, "block_len must be >= 1");
        assert_eq!(cal.len(), 256, "calibration table must cover all operand pairs");
        let full_scale = engine.full_scale();
        Self {
            engine,
            kernel,
            sampler,
            cal,
            full_scale,
            block_len,
            block: TrialBlock::with_capacity(block_len),
        }
    }

    /// One tiled matrix–vector product. `first_item` is the global item
    /// index of the product's first op; ops occupy the contiguous range
    /// `first_item .. first_item + result.ops`, so deviates — and hence
    /// every output — are independent of `block_len`, shard cuts, and
    /// thread schedule.
    pub fn matvec(&mut self, w: &QuantMatrix, x: &QuantVec, first_item: u64) -> MatvecResult {
        assert_eq!(w.cols, x.len(), "matvec shape mismatch");
        assert_eq!(w.qp.bits, x.qp.bits, "weight/activation word widths differ");
        let words = w.qp.words() as usize;
        let total = w.rows as u64 * w.cols as u64 * (words * words) as u64;
        let mut acc = vec![0i64; w.rows];
        let mut energy = 0.0f64;
        let mut faults = 0u64;
        let mut op = 0u64;
        while op < total {
            let n = self.block_len.min((total - op) as usize);
            self.block.reset(n);
            let (dvth, dbeta) = self.block.deviates_mut();
            self.sampler.fill_block(first_item + op, dvth, dbeta);
            for lane in 0..n {
                let (j, i, pw, xw) = decode(op + lane as u64, w.cols, words);
                let a = nibble(w.at(j, i).unsigned_abs(), pw);
                let b = nibble(x.q[i].unsigned_abs(), xw);
                self.block.set_operands(lane, a, b);
            }
            self.kernel.simulate(self.engine, &mut self.block);
            for lane in 0..n {
                let (j, i, pw, xw) = decode(op + lane as u64, w.cols, words);
                let (wq, xq) = (w.at(j, i), x.q[i]);
                let (a, b) = self.block.operands(lane);
                // Offset-calibrated reconstruction: exact word product
                // plus the rounded deviation from the nominal output.
                let v = f64::from(self.block.out.v_mult[lane]);
                let cal = f64::from(self.cal[usize::from(a) * 16 + usize::from(b)]);
                let delta = ((v - cal) / self.full_scale * 225.0).round() as i64;
                let prod = (i64::from(a) * i64::from(b) + delta).clamp(0, 225);
                let sign: i64 = if (wq < 0) != (xq < 0) { -1 } else { 1 };
                acc[j] += sign * (prod << (4 * (pw + xw)));
                // lint:allow(D2): energy folds in fixed lane order within one tile
                energy += f64::from(self.block.out.energy[lane]);
                faults += u64::from(self.block.out.fault[lane] > 0.5);
            }
            op += n as u64;
        }
        MatvecResult { acc, energy, faults, ops: total }
    }
}

/// Canonical op order: `(neuron, input, weight word, activation word)`,
/// activation word fastest.
fn decode(k: u64, cols: usize, words: usize) -> (usize, usize, u32, u32) {
    let w2 = (words * words) as u64;
    let per_row = cols as u64 * w2;
    let j = (k / per_row) as usize;
    let rem = k % per_row;
    let i = (rem / w2) as usize;
    let p = rem % w2;
    (j, i, (p / words as u64) as u32, (p % words as u64) as u32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mac::{BlockKernel, ScalarKernel, Variant};
    use crate::nn::quant::QParams;
    use crate::params::Params;

    fn engine(v: Variant) -> NativeMacEngine {
        let p = Params::default();
        NativeMacEngine::new(p, v.config(&p))
    }

    fn fixture_mat(bits: u32) -> (QuantMatrix, QuantVec) {
        let qp = QParams::symmetric(1.0, bits);
        let m = QuantMatrix { rows: 2, cols: 3, q: vec![3, -5, 0, 2, 7, -1], qp };
        let x = QuantVec { q: vec![4, 9, -2], qp };
        (m, x)
    }

    #[test]
    fn decode_covers_the_canonical_order() {
        // 2 cols, 2 words: 16 ops per row pair
        let seen: Vec<_> = (0..8).map(|k| decode(k, 2, 2)).collect();
        assert_eq!(seen[0], (0, 0, 0, 0));
        assert_eq!(seen[1], (0, 0, 0, 1));
        assert_eq!(seen[2], (0, 0, 1, 0));
        assert_eq!(seen[4], (0, 1, 0, 0));
        assert_eq!(decode(8, 2, 2), (1, 0, 0, 0));
        assert_eq!(decode(5, 3, 1), (1, 2, 0, 0));
    }

    #[test]
    fn noise_off_reproduces_the_exact_integer_product() {
        let e = engine(Variant::Smart);
        let quiet = MismatchSampler::new(7, 0.0, 0.0);
        for bits in [4u32, 8] {
            let (m, x) = fixture_mat(bits);
            let mut tiler = Tiler::new(&e, &ScalarKernel, &quiet, 5);
            let r = tiler.matvec(&m, &x, 1000);
            assert_eq!(r.acc, vec![3 * 4 - 5 * 9, 2 * 4 + 7 * 9 + 2], "bits={bits}");
            assert_eq!(r.ops, 6 * u64::from(bits / 4) * u64::from(bits / 4));
            assert_eq!(r.faults, 0);
            assert!(r.energy > 0.0);
        }
    }

    #[test]
    fn block_size_and_kernel_do_not_change_results() {
        let e = engine(Variant::Aid);
        let p = Params::default();
        let noisy = MismatchSampler::new(2022, p.circuit.sigma_vth, p.circuit.sigma_beta);
        let (m, x) = fixture_mat(8);
        let mut base = Tiler::new(&e, &ScalarKernel, &noisy, 7);
        let want = base.matvec(&m, &x, 64);
        // shards share one calibration table; results must not move
        let cal = Tiler::calibrate(&e);
        for block_len in [1usize, 3, 64] {
            let mut t = Tiler::with_calibration(&e, &BlockKernel, &noisy, block_len, cal.clone());
            let got = t.matvec(&m, &x, 64);
            assert_eq!(got.acc, want.acc, "block_len={block_len}");
            assert_eq!(got.energy.to_bits(), want.energy.to_bits(), "block_len={block_len}");
            assert_eq!(got.faults, want.faults);
        }
        // a different item base draws different deviates
        let other = base.matvec(&m, &x, 65);
        assert_ne!(other.energy.to_bits(), want.energy.to_bits());
    }

    #[test]
    fn tiler_block_reuse_is_stateless() {
        let e = engine(Variant::Smart);
        let p = Params::default();
        let noisy = MismatchSampler::new(5, p.circuit.sigma_vth, p.circuit.sigma_beta);
        let (m, x) = fixture_mat(4);
        let mut t = Tiler::new(&e, &BlockKernel, &noisy, 4);
        let a = t.matvec(&m, &x, 0);
        let _ = t.matvec(&m, &x, 999);
        let b = t.matvec(&m, &x, 0);
        assert_eq!(a, b);
    }
}
