//! The noisy-inference campaign: sharded trials, canonical-order folds,
//! energy costing, and the `smart infer` CSV/JSON artifacts.

use std::fmt::Write as _;
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::coordinator::{execute_sharded_traced, resolve_threads, shard_range, DEFAULT_BLOCK_LEN};
use crate::energy::EnergyModel;
use crate::mac::{
    BlockKernel, FastKernel, KernelKind, NativeMacEngine, ScalarKernel, SimKernel, Variant,
};
use crate::metrics::OnlineStats;
use crate::montecarlo::MismatchSampler;
use crate::obs::{Stopwatch, Tracer};
use crate::params::Params;
use crate::report::{canon, csv_cell};
use crate::util::json::{self, Value};

use super::model::ModelSpec;
use super::tiler::Tiler;

/// Execution knobs of one inference campaign. `shards`/`threads`/`block`
/// are pure performance knobs — the report and artifacts are
/// byte-identical for every combination (DESIGN.md §10). The `kernel`
/// tier is identity: `scalar` and `block` are bit-identical to each
/// other, while `fast` is tolerance-bounded (DESIGN.md §13), and the
/// executing kernel is recorded in `infer.json`.
#[derive(Debug, Clone)]
pub struct InferOptions {
    /// Inference trials (0 = the model file's `trials`).
    pub trials: u32,
    /// Shards the trial space splits into (0 = auto).
    pub shards: usize,
    /// Worker threads (0 = auto).
    pub threads: usize,
    /// Lanes per [`crate::mac::TrialBlock`] (0 = auto, 256).
    pub block: usize,
    /// Design variant executing the MACs.
    pub variant: Variant,
    /// Simulation kernel executing the MAC ops: the lockstep
    /// [`BlockKernel`] default, the per-op [`ScalarKernel`] oracle
    /// (bit-identical; for cross-checks), or the [`FastKernel`] surrogate
    /// tier (DESIGN.md §13).
    pub kernel: KernelKind,
    /// Zero the mismatch sigmas: the noisy pass must then equal the
    /// exact integer pipeline bit for bit.
    pub noise_off: bool,
    /// Write `infer.csv` / `infer.json` to `out_dir`.
    pub write_artifacts: bool,
    /// Artifact directory.
    pub out_dir: PathBuf,
    /// Trace sink (DESIGN.md §15): emits `infer` / `trial_block` /
    /// `worker` spans when enabled. Purely observational — artifacts are
    /// byte-identical whether tracing is on or off (`tests/obs.rs`).
    pub tracer: Tracer,
}

impl Default for InferOptions {
    fn default() -> Self {
        Self {
            trials: 0,
            shards: 0,
            threads: 0,
            block: 0,
            variant: Variant::Smart,
            kernel: KernelKind::Block,
            noise_off: false,
            write_artifacts: false,
            out_dir: PathBuf::from("target/infer"),
            tracer: Tracer::disabled(),
        }
    }
}

/// One inference trial's outcome (a row of `infer.csv`).
#[derive(Debug, Clone, PartialEq)]
pub struct TrialRecord {
    /// Trial index (also the Monte-Carlo instance index).
    pub trial: u64,
    /// Synthetic ground-truth class.
    pub label: usize,
    /// Exact integer pipeline's top-1 class.
    pub ideal_pred: usize,
    /// Noisy analog pipeline's top-1 class.
    pub noisy_pred: usize,
    /// Relative L2 error of the noisy output scores vs the exact ones
    /// (canonicalized to artifact precision).
    pub out_err: f64,
    /// Raw dynamic bitline energy of the trial (J), canonical op order.
    pub energy_raw: f64,
    /// Energy per inference through the peripheral model (pJ,
    /// canonicalized).
    pub energy_pj: f64,
    /// Saturation-exit faults across the trial's MAC ops.
    pub faults: u64,
}

/// A finished inference campaign.
#[derive(Debug, Clone)]
pub struct InferReport {
    /// Model label (from the spec).
    pub name: String,
    /// Variant that executed the MACs.
    pub variant: Variant,
    /// Kernel name (`scalar`, `block`, or `fast`).
    pub kernel: &'static str,
    /// Trials run.
    pub trials: u32,
    /// Analog MAC ops per inference.
    pub macs_per_inference: u64,
    /// Exact-pipeline top-1 accuracy on the synthetic labels.
    pub ideal_accuracy: f64,
    /// Noisy-pipeline top-1 accuracy on the synthetic labels.
    pub noisy_accuracy: f64,
    /// Fraction of trials where noisy and exact top-1 agree.
    pub agreement: f64,
    /// Per-trial relative output-error statistics (canonical order).
    pub out_err: OnlineStats,
    /// Fault rate over all MAC ops.
    pub fault_rate: f64,
    /// Mean energy per MAC through the peripheral model (pJ).
    pub energy_per_mac_pj: f64,
    /// Mean energy per inference (pJ).
    pub energy_per_inference_pj: f64,
    /// Operating frequency of the executing variant (MHz).
    pub freq_mhz: f64,
    /// Per-trial outcomes in canonical trial order.
    pub records: Vec<TrialRecord>,
    /// CSV artifact path, when written.
    pub csv_path: Option<PathBuf>,
    /// JSON artifact path, when written.
    pub json_path: Option<PathBuf>,
    /// Campaign wall-clock (reporting only; never in the artifacts).
    pub wall: std::time::Duration,
}

impl InferReport {
    /// Accuracy lost to analog noise: ideal minus noisy top-1.
    pub fn accuracy_delta(&self) -> f64 {
        self.ideal_accuracy - self.noisy_accuracy
    }

    /// MAC evaluations per wall-clock second.
    pub fn throughput(&self) -> f64 {
        self.macs_per_inference as f64 * f64::from(self.trials)
            / self.wall.as_secs_f64().max(1e-12)
    }
}

/// Relative L2 distance between the noisy and exact output scores.
fn rel_l2(noisy: &[f64], exact: &[f64]) -> f64 {
    // lint:allow(D2): fixed-order fold over one output vector (score-length, tiny)
    let num: f64 = noisy.iter().zip(exact).map(|(&n, &e)| (n - e) * (n - e)).sum();
    // lint:allow(D2): fixed-order fold over one output vector (score-length, tiny)
    let den: f64 = exact.iter().map(|&e| e * e).sum();
    (num / den.max(1e-24)).sqrt()
}

/// Run a sharded noisy-inference campaign over `spec`'s synthetic set.
///
/// Trial `t`'s input, weights, and per-op mismatch deviates are pure
/// functions of `(spec.seed, t)`, trials fold in canonical order, and
/// artifact numbers are canonicalized — so the report and any written
/// artifacts are byte-identical for every `shards`/`threads`/`block`
/// choice under a fixed kernel (pinned in `tests/nn_infer.rs`). The
/// `scalar` and `block` tiers are additionally bit-identical to each
/// other; `fast` is tolerance-bounded (DESIGN.md §13).
///
/// ```
/// use smart_insram::nn::{run_infer, InferOptions, ModelSpec};
/// use smart_insram::params::Params;
///
/// let spec = ModelSpec::fixture();
/// let opts = InferOptions { trials: 2, noise_off: true, ..InferOptions::default() };
/// let r = run_infer(&Params::default(), &spec, &opts).unwrap();
/// assert_eq!(r.trials, 2);
/// // with mismatch off, the analog pipeline is the exact pipeline
/// assert_eq!(r.agreement, 1.0);
/// assert_eq!(r.out_err.max(), 0.0);
/// ```
pub fn run_infer(params: &Params, spec: &ModelSpec, opts: &InferOptions) -> Result<InferReport> {
    let cfg = opts.variant.config(params);
    let engine = NativeMacEngine::new(*params, cfg);
    // One calibration table (256 nominal transients) shared by every
    // shard's tiler — cloning 1 KB beats re-simulating it per shard.
    let cal = Tiler::calibrate(&engine);
    run_infer_on(params, spec, opts, &engine, kernel_for(opts.kernel), &cal)
}

/// Run several inference campaigns that share one variant and kernel
/// tier through ONE engine, ONE kernel instance, and ONE calibration
/// table, returning one report per job in input order.
///
/// The serving path's `/v1/infer` cross-request batching primitive
/// (DESIGN.md §14): engine construction and the tiler calibration
/// transients amortize across the whole group. Each job still runs
/// [`run_infer_on`]'s exact trial loop, so every report — and therefore
/// every [`infer_json`] body — is **byte-identical** to a solo
/// [`run_infer`] of the same job for any batch size (pinned in
/// `tests/serve.rs`).
pub fn run_infer_batch(
    params: &Params,
    jobs: &[(ModelSpec, InferOptions)],
) -> Result<Vec<InferReport>> {
    let Some((_, first)) = jobs.first() else {
        return Ok(Vec::new());
    };
    for (_, o) in jobs {
        anyhow::ensure!(
            o.variant == first.variant && o.kernel == first.kernel,
            "batched inferences must share one variant and kernel tier (got {}/{} vs {}/{})",
            o.variant.token(),
            o.kernel.token(),
            first.variant.token(),
            first.kernel.token()
        );
    }
    let cfg = first.variant.config(params);
    let engine = NativeMacEngine::new(*params, cfg);
    let kernel = kernel_for(first.kernel);
    let cal = Tiler::calibrate(&engine);
    jobs.iter().map(|(spec, opts)| run_infer_on(params, spec, opts, &engine, kernel, &cal)).collect()
}

/// Map a kernel tier to its shared kernel instance.
fn kernel_for(kind: KernelKind) -> &'static dyn SimKernel {
    match kind {
        KernelKind::Scalar => &ScalarKernel,
        KernelKind::Block => &BlockKernel,
        KernelKind::Fast => FastKernel::shared(),
    }
}

/// The inference campaign core over an explicit engine, kernel, and
/// calibration table — the shared substrate of [`run_infer`] (which
/// builds all three for one spec) and [`run_infer_batch`] (which builds
/// them once per compatible group).
fn run_infer_on(
    params: &Params,
    spec: &ModelSpec,
    opts: &InferOptions,
    engine: &NativeMacEngine,
    kernel: &dyn SimKernel,
    cal: &[f32],
) -> Result<InferReport> {
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    let trials = if opts.trials > 0 { opts.trials } else { spec.trials };
    let model = spec.build(trials);
    let cfg = opts.variant.config(params);
    let (sv, sb) = if opts.noise_off {
        (0.0, 0.0)
    } else {
        (params.circuit.sigma_vth, params.circuit.sigma_beta)
    };
    let sampler = MismatchSampler::new(spec.seed, sv, sb);
    let emodel = EnergyModel::default();
    let v_wl_max = engine.dac().v_wl(15);
    let ops = model.ops_per_trial();

    let block_len = if opts.block > 0 { opts.block } else { DEFAULT_BLOCK_LEN };
    let threads = resolve_threads(opts.threads);
    let total = u64::from(trials);
    let n_shards =
        if opts.shards > 0 { opts.shards } else { (total as usize).min(threads * 4).max(1) };

    let mut ispan = opts.tracer.span("infer");
    ispan.attr_str("model", &spec.name);
    ispan.attr_str("kernel", kernel.name());
    ispan.attr_u64("trials", total);
    ispan.attr_u64("shards", n_shards as u64);
    ispan.attr_u64("threads", threads as u64);
    let parent = ispan.id();
    let counters_before = kernel.counters();

    let t0 = Stopwatch::start();
    let run_shard = |shard: usize| {
        let mut sspan = opts.tracer.span_started("trial_block", parent, Stopwatch::start());
        let (start, end) = shard_range(total, n_shards, shard);
        sspan.attr_u64("shard", shard as u64);
        sspan.attr_u64("trials", end - start);
        let mut tiler = Tiler::with_calibration(engine, kernel, &sampler, block_len, cal.to_vec());
        let mut recs = Vec::with_capacity((end - start) as usize);
        for t in start..end {
            let (label, xs) = model.spec.trial_input(t);
            let x0 = model.quantize_input(&xs);
            let (ideal_pred, ideal_y) = model.forward_exact(&x0);
            let base = t * ops;
            let mut x = x0;
            let mut energy_raw = 0.0f64;
            let mut faults = 0u64;
            let last = model.layers.len() - 1;
            let mut final_acc = Vec::new();
            for l in 0..model.layers.len() {
                let r = tiler.matvec(&model.layers[l].w, &x, base + model.layer_item_offset(l));
                // lint:allow(D2): per-trial energy folds in fixed layer order
                energy_raw += r.energy;
                faults += r.faults;
                if l < last {
                    x = model.activate(l, &r.acc);
                } else {
                    final_acc = r.acc;
                }
            }
            let noisy_pred = model.predict(&final_acc);
            let noisy_y = model.output_real(&final_acc);
            let energy_pj =
                canon(emodel.op_energy(&cfg, energy_raw / ops as f64, v_wl_max) * ops as f64 * 1e12);
            recs.push(TrialRecord {
                trial: t,
                label,
                ideal_pred,
                noisy_pred,
                out_err: canon(rel_l2(&noisy_y, &ideal_y)),
                energy_raw,
                energy_pj,
                faults,
            });
        }
        opts.tracer.finish(sspan);
        recs
    };

    // Canonical-order fold: execute_sharded hands shards back in shard
    // (== trial) order regardless of the thread schedule.
    let mut records: Vec<TrialRecord> = Vec::with_capacity(total as usize);
    let mut out_err = OnlineStats::new();
    let mut raw_energy = OnlineStats::new();
    let (mut ideal_ok, mut noisy_ok, mut agree, mut faults) = (0u64, 0u64, 0u64, 0u64);
    execute_sharded_traced(n_shards, threads, &opts.tracer, parent, run_shard, |_, recs| {
        for r in recs {
            out_err.push(r.out_err);
            raw_energy.push(r.energy_raw);
            ideal_ok += u64::from(r.ideal_pred == r.label);
            noisy_ok += u64::from(r.noisy_pred == r.label);
            agree += u64::from(r.noisy_pred == r.ideal_pred);
            faults += r.faults;
            records.push(r);
        }
    });
    let wall = t0.elapsed();
    let delta = kernel.counters().since(&counters_before);
    if delta != crate::mac::KernelCounters::default() {
        ispan.attr_u64("lanes", delta.lanes);
        ispan.attr_u64("fallbacks", delta.fallbacks);
        ispan.attr_u64("table_builds", delta.table_builds);
    }
    opts.tracer.finish(ispan);

    let cost = emodel.cost(&cfg, raw_energy.mean() / ops as f64, engine.full_scale(), v_wl_max);
    let rate = |n: u64| canon(n as f64 / total as f64);
    let mut report = InferReport {
        name: spec.name.clone(),
        variant: opts.variant,
        kernel: kernel.name(),
        trials,
        macs_per_inference: ops,
        ideal_accuracy: rate(ideal_ok),
        noisy_accuracy: rate(noisy_ok),
        agreement: rate(agree),
        out_err,
        fault_rate: canon(faults as f64 / (ops * total) as f64),
        energy_per_mac_pj: canon(cost.energy * 1e12),
        energy_per_inference_pj: canon(cost.energy * ops as f64 * 1e12),
        freq_mhz: canon(cost.frequency / 1e6),
        records,
        csv_path: None,
        json_path: None,
        wall,
    };
    if opts.write_artifacts {
        std::fs::create_dir_all(&opts.out_dir)
            .with_context(|| format!("creating {}", opts.out_dir.display()))?;
        let csv_path = opts.out_dir.join("infer.csv");
        let json_path = opts.out_dir.join("infer.json");
        std::fs::write(&csv_path, render_csv(&report))
            .with_context(|| format!("writing {}", csv_path.display()))?;
        std::fs::write(&json_path, infer_json(spec, &report))
            .with_context(|| format!("writing {}", json_path.display()))?;
        report.csv_path = Some(csv_path);
        report.json_path = Some(json_path);
    }
    Ok(report)
}

/// Column order of the per-trial CSV artifact.
const CSV_HEADER: &str = "trial,label,ideal_pred,noisy_pred,agree,out_err,energy_pj,faults";

fn render_csv(r: &InferReport) -> String {
    let mut s = String::with_capacity(r.records.len() * 64 + CSV_HEADER.len() + 1);
    s.push_str(CSV_HEADER);
    s.push('\n');
    for t in &r.records {
        let _ = writeln!(
            s,
            "{},{},{},{},{},{},{},{}",
            t.trial,
            t.label,
            t.ideal_pred,
            t.noisy_pred,
            u8::from(t.noisy_pred == t.ideal_pred),
            csv_cell(t.out_err),
            csv_cell(t.energy_pj),
            t.faults
        );
    }
    s
}

/// Render the canonical `infer.json` artifact for a finished inference
/// campaign. The single JSON encoder for inference results: the CLI
/// `--json` artifact writer and `smart serve`'s `POST /v1/infer`
/// responses both call it, so a served inference is byte-identical to
/// the `smart infer --json` artifact of the same spec (every float is
/// already canonicalized by [`run_infer`]; wall-clock never appears).
pub fn infer_json(spec: &ModelSpec, r: &InferReport) -> String {
    let mut root = std::collections::BTreeMap::new();
    let mut put = |k: &str, v: Value| {
        root.insert(k.to_string(), v);
    };
    put("name", Value::Str(r.name.clone()));
    put("variant", Value::Str(r.variant.token().to_string()));
    put("kernel", Value::Str(r.kernel.to_string()));
    put("seed", Value::Num(spec.seed as f64));
    put("bits", Value::Num(f64::from(spec.bits)));
    put("trials", Value::Num(f64::from(r.trials)));
    put("macs_per_inference", Value::Num(r.macs_per_inference as f64));
    put("ideal_accuracy", Value::Num(r.ideal_accuracy));
    put("noisy_accuracy", Value::Num(r.noisy_accuracy));
    put("accuracy_delta", Value::Num(canon(r.accuracy_delta())));
    put("agreement", Value::Num(r.agreement));
    put("out_err_mean", Value::Num(canon(r.out_err.mean())));
    put("out_err_max", Value::Num(canon(r.out_err.max())));
    put("fault_rate", Value::Num(r.fault_rate));
    put("energy_per_mac_pj", Value::Num(r.energy_per_mac_pj));
    put("energy_per_inference_pj", Value::Num(r.energy_per_inference_pj));
    put("freq_mhz", Value::Num(r.freq_mhz));
    let rows: Vec<Value> = r
        .records
        .iter()
        .map(|t| {
            let mut m = std::collections::BTreeMap::new();
            m.insert("trial".to_string(), Value::Num(t.trial as f64));
            m.insert("label".to_string(), Value::Num(t.label as f64));
            m.insert("ideal_pred".to_string(), Value::Num(t.ideal_pred as f64));
            m.insert("noisy_pred".to_string(), Value::Num(t.noisy_pred as f64));
            m.insert("agree".to_string(), Value::Bool(t.noisy_pred == t.ideal_pred));
            m.insert("out_err".to_string(), Value::Num(t.out_err));
            m.insert("energy_pj".to_string(), Value::Num(t.energy_pj));
            m.insert("faults".to_string(), Value::Num(t.faults as f64));
            Value::Obj(m)
        })
        .collect();
    put("records", Value::Arr(rows));
    let mut text = json::to_string_pretty(&Value::Obj(root));
    text.push('\n');
    text
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn canon_matches_the_csv_cell_precision() {
        let x = canon(0.012_345_678_9);
        assert_eq!(canon(x), x);
        assert_eq!(csv_cell(x), "1.234568e-2");
        assert!(canon(f64::NAN).is_nan());
        assert_eq!(canon(0.0), 0.0);
    }

    #[test]
    fn rel_l2_basics() {
        assert_eq!(rel_l2(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        let e = rel_l2(&[1.0, 2.0], &[1.0, 1.0]);
        assert!((e - 1.0 / (2.0f64).sqrt()).abs() < 1e-12);
        // all-zero reference never divides by zero
        assert!(rel_l2(&[1.0], &[0.0]).is_finite());
    }

    #[test]
    fn infer_runs_end_to_end_on_the_fixture() {
        let spec = ModelSpec::fixture();
        let opts = InferOptions { trials: 4, ..InferOptions::default() };
        let r = run_infer(&Params::default(), &spec, &opts).unwrap();
        assert_eq!(r.trials, 4);
        assert_eq!(r.records.len(), 4);
        assert_eq!(r.macs_per_inference, 8 * 16 + 4 * 8);
        assert!(r.energy_per_inference_pj > 0.0);
        assert!((0.0..=1.0).contains(&r.noisy_accuracy));
        assert!(r.records.windows(2).all(|w| w[0].trial < w[1].trial));
    }

    #[test]
    fn batched_inferences_byte_match_their_solo_runs() {
        let p = Params::default();
        let mut other = ModelSpec::fixture();
        other.seed ^= 3; // same variant/kernel, different model stream
        let opts = InferOptions { trials: 3, ..InferOptions::default() };
        let jobs = vec![(ModelSpec::fixture(), opts.clone()), (other, opts)];
        let batch = run_infer_batch(&p, &jobs).unwrap();
        assert_eq!(batch.len(), jobs.len());
        for ((spec, o), r) in jobs.iter().zip(&batch) {
            let solo = run_infer(&p, spec, o).unwrap();
            assert_eq!(infer_json(spec, r), infer_json(spec, &solo));
        }
    }

    #[test]
    fn batched_inferences_reject_mixed_tiers() {
        let p = Params::default();
        let jobs = vec![
            (ModelSpec::fixture(), InferOptions::default()),
            (
                ModelSpec::fixture(),
                InferOptions { variant: Variant::Aid, ..InferOptions::default() },
            ),
        ];
        let err = run_infer_batch(&p, &jobs).unwrap_err().to_string();
        assert!(err.contains("variant"), "{err}");
        assert!(run_infer_batch(&p, &[]).unwrap().is_empty());
    }

    #[test]
    fn artifacts_render_deterministically() {
        let spec = ModelSpec::fixture();
        let opts = InferOptions { trials: 3, ..InferOptions::default() };
        let p = Params::default();
        let a = run_infer(&p, &spec, &opts).unwrap();
        let b = run_infer(&p, &spec, &opts).unwrap();
        assert_eq!(render_csv(&a), render_csv(&b));
        assert_eq!(infer_json(&spec, &a), infer_json(&spec, &b));
        assert!(render_csv(&a).starts_with(CSV_HEADER));
    }
}
