//! Dense NN layers over quantized weights, with the exact-integer
//! reference path the noisy MAC execution is compared against.

use anyhow::Result;

use crate::util::json::Value;

use super::quant::{QuantMatrix, QuantVec};

/// Shape of one dense layer as written in the model file.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerSpec {
    /// Input features.
    pub inputs: usize,
    /// Output neurons.
    pub outputs: usize,
    /// Apply ReLU before handing activations to the next layer.
    pub relu: bool,
}

impl LayerSpec {
    /// Parse one `[[layers]]` table: `inputs`/`outputs` required,
    /// `relu` optional (default false).
    pub fn from_value(v: &Value) -> Result<Self> {
        let dim = |k: &str| -> anyhow::Result<usize> {
            let n = v
                .get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow::anyhow!("layers.{k} missing or not an integer"))?;
            usize::try_from(n).map_err(|_| anyhow::anyhow!("layers.{k} = {n} exceeds usize"))
        };
        Ok(Self {
            inputs: dim("inputs")?,
            outputs: dim("outputs")?,
            relu: v.get("relu").and_then(Value::as_bool).unwrap_or(false),
        })
    }
}

/// One dense layer: a quantized weight matrix plus its activation kind.
#[derive(Debug, Clone)]
pub struct DenseLayer {
    /// Per-layer symmetrically quantized weights.
    pub w: QuantMatrix,
    /// Apply ReLU before requantizing for the next layer.
    pub relu: bool,
}

impl DenseLayer {
    /// Exact integer matrix–vector product: `acc_j = sum_i w_ji * x_i`
    /// in signed integer code space — the bit-exact reference the tiled
    /// analog execution reproduces when mismatch is off.
    pub fn forward_exact(&self, x: &QuantVec) -> Vec<i64> {
        assert_eq!(x.len(), self.w.cols, "layer input shape mismatch");
        (0..self.w.rows)
            .map(|j| {
                (0..self.w.cols)
                    .map(|i| i64::from(self.w.at(j, i)) * i64::from(x.q[i]))
                    .sum()
            })
            .collect()
    }

    /// Analog MAC operations this layer tiles into, for operands split
    /// into `words` 4-bit array words each (`rows * cols * words^2`).
    pub fn ops(&self, words: u32) -> u64 {
        self.w.rows as u64 * self.w.cols as u64 * u64::from(words) * u64::from(words)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::quant::QParams;
    use crate::util::toml_lite;

    #[test]
    fn exact_forward_matches_hand_computation() {
        let w = QuantMatrix {
            rows: 2,
            cols: 2,
            q: vec![3, -5, 2, 7],
            qp: QParams::symmetric(1.0, 4),
        };
        let layer = DenseLayer { w, relu: false };
        let x = QuantVec { q: vec![4, 9], qp: QParams::symmetric(1.0, 4) };
        assert_eq!(layer.forward_exact(&x), vec![3 * 4 - 5 * 9, 2 * 4 + 7 * 9]);
        assert_eq!(layer.ops(1), 4);
        assert_eq!(layer.ops(2), 16);
    }

    #[test]
    fn spec_parses_with_relu_default() {
        let doc = toml_lite::parse("[[layers]]\ninputs = 16\noutputs = 8\nrelu = true\n").unwrap();
        let arr = doc.get("layers").unwrap().as_arr().unwrap();
        let spec = LayerSpec::from_value(&arr[0]).unwrap();
        assert_eq!(spec, LayerSpec { inputs: 16, outputs: 8, relu: true });
        let doc = toml_lite::parse("[[layers]]\ninputs = 4\noutputs = 2\n").unwrap();
        let spec = LayerSpec::from_value(&doc.get("layers").unwrap().as_arr().unwrap()[0]).unwrap();
        assert!(!spec.relu);
        let doc = toml_lite::parse("[[layers]]\ninputs = 4\n").unwrap();
        assert!(LayerSpec::from_value(&doc.get("layers").unwrap().as_arr().unwrap()[0]).is_err());
    }
}
