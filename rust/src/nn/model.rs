//! Model specification, reproducible weight init, the synthetic
//! classification set, and the exact-integer reference forward pass.

use std::path::Path;

use anyhow::{Context, Result};

use crate::montecarlo::SplitMix64;
use crate::util::{json::Value, toml_lite};

use super::layer::{DenseLayer, LayerSpec};
use super::quant::{QParams, QuantMatrix, QuantVec};
use super::tensor::Tensor;

/// Stream salt for per-layer weight draws (distinct from the data and
/// mismatch streams, so no two generators ever share a state).
const WEIGHT_SALT: u64 = 0x0057_E167_0000_0001;
/// Stream salt for per-trial dataset draws.
const DATA_SALT: u64 = 0x00DA_7A5E_0000_0002;

/// The embedded fixture model: a 2-layer 4-bit MLP on the 4-class
/// synthetic band dataset — the checked-in `configs/nn.toml`, compiled
/// into the crate so it needs no external file and cannot drift from
/// what the CLI/CI run.
const FIXTURE_TOML: &str = include_str!("../../../configs/nn.toml");

/// The synthetic classification set: `classes` band-prototype patterns
/// over `features` inputs, jittered per trial from a seeded counter
/// stream (trial `t` is a pure function of `(seed, t)`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DatasetSpec {
    /// Number of classes (== the last layer's output count).
    pub classes: usize,
    /// Input features (== the first layer's input count).
    pub features: usize,
    /// Uniform jitter amplitude added to each feature (0..=0.5).
    pub jitter: f64,
}

impl DatasetSpec {
    /// Parse the `[dataset]` table.
    pub fn from_value(v: &Value) -> Result<Self> {
        let dim = |k: &str| -> anyhow::Result<usize> {
            let n = v
                .get(k)
                .and_then(Value::as_u64)
                .ok_or_else(|| anyhow::anyhow!("dataset.{k} missing or not an integer"))?;
            usize::try_from(n).map_err(|_| anyhow::anyhow!("dataset.{k} = {n} exceeds usize"))
        };
        Ok(Self {
            classes: dim("classes")?,
            features: dim("features")?,
            jitter: v.get("jitter").and_then(Value::as_f64).unwrap_or(0.15),
        })
    }

    /// Class band owning feature `i`: features are split into
    /// contiguous per-class bands (the prototype structure the weight
    /// init mirrors).
    pub fn feature_tag(&self, i: usize) -> usize {
        i * self.classes / self.features
    }
}

/// Everything needed to reproduce a noisy-inference workload bit-for-bit
/// (see the `configs/nn.toml` format).
///
/// ```
/// let spec = smart_insram::nn::ModelSpec::fixture();
/// assert!(spec.validate().is_ok());
/// assert_eq!(spec.layers.len(), 2);
/// assert_eq!(spec.dataset.classes, 4);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    /// Human label for reports and artifacts.
    pub name: String,
    /// Seed for the weight, dataset, and mismatch streams.
    pub seed: u64,
    /// Default inference trial count (CLI `--trials` overrides).
    pub trials: u32,
    /// Operand magnitude width (4 or 8 bits — 1 or 2 array words).
    pub bits: u32,
    /// The synthetic classification set.
    pub dataset: DatasetSpec,
    /// Dense layer shapes, input to output.
    pub layers: Vec<LayerSpec>,
}

impl ModelSpec {
    /// The embedded tiny fixture model (no external file needed).
    pub fn fixture() -> Self {
        // lint:allow(D4): compile-time-embedded fixture; failure is a build defect, not input
        Self::parse(FIXTURE_TOML).expect("embedded fixture model parses")
    }

    /// Load and parse a model file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse a model document (TOML-lite, see the module docs).
    pub fn parse(text: &str) -> Result<Self> {
        let doc = toml_lite::parse(text).map_err(|e| anyhow::anyhow!("model TOML: {e}"))?;
        Self::from_value(&doc)
    }

    /// Build a spec from an already-parsed config tree. TOML-lite files
    /// and JSON documents parse into the same [`Value`] shape, so this is
    /// also how `smart serve` accepts `nn.toml`-mirroring JSON request
    /// bodies on `POST /v1/infer`.
    pub fn from_value(doc: &Value) -> Result<Self> {
        let name = doc.get("name").and_then(Value::as_str).unwrap_or("nn").to_string();
        let u = |k: &str, default: u64| doc.get(k).and_then(Value::as_u64).unwrap_or(default);
        let dataset = DatasetSpec::from_value(
            doc.get("dataset").ok_or_else(|| anyhow::anyhow!("no [dataset] in model"))?,
        )?;
        let mut layers = Vec::new();
        let arr = doc
            .get("layers")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("no [[layers]] in model"))?;
        for (i, l) in arr.iter().enumerate() {
            layers.push(LayerSpec::from_value(l).with_context(|| format!("layer #{i}"))?);
        }
        // Range-checked narrowing: this parser also serves `smart serve`'s
        // untrusted POST /v1/infer bodies, where a wrapped integer
        // (trials = 2^32 + 8 -> 8) would silently run a different
        // campaign than requested and cache it under the wrapped key.
        let narrow = |k: &str, v: u64| {
            u32::try_from(v).map_err(|_| anyhow::anyhow!("model {k} = {v} exceeds u32"))
        };
        let spec = Self {
            name,
            seed: u("seed", 2022),
            trials: narrow("trials", u("trials", 64))?,
            bits: narrow("bits", u("bits", 4))?,
            dataset,
            layers,
        };
        spec.validate().map_err(|e| anyhow::anyhow!(e))?;
        Ok(spec)
    }

    /// Check the spec is runnable and exactly reproducible.
    pub fn validate(&self) -> Result<(), String> {
        if self.bits != 4 && self.bits != 8 {
            return Err(format!("bits must be 4 or 8 (array words), got {}", self.bits));
        }
        if self.trials == 0 {
            return Err("trials must be >= 1".into());
        }
        // Same f64-representability bound as CampaignSpec::validate.
        if self.seed >= (1u64 << 53) {
            return Err("seed must be < 2^53 (config numbers are f64)".into());
        }
        if self.layers.is_empty() {
            return Err("model needs at least one [[layers]] entry".into());
        }
        for (i, l) in self.layers.iter().enumerate() {
            if l.inputs == 0 || l.outputs == 0 {
                return Err(format!("layer #{i} has a zero dimension"));
            }
        }
        for (i, pair) in self.layers.windows(2).enumerate() {
            if pair[0].outputs != pair[1].inputs {
                return Err(format!(
                    "layer #{i} outputs {} != layer #{} inputs {}",
                    pair[0].outputs,
                    i + 1,
                    pair[1].inputs
                ));
            }
        }
        if self.dataset.classes < 2 {
            return Err("dataset.classes must be >= 2".into());
        }
        if self.dataset.features != self.layers[0].inputs {
            return Err(format!(
                "dataset.features {} != first layer inputs {}",
                self.dataset.features, self.layers[0].inputs
            ));
        }
        let Some(last) = self.layers.last() else {
            return Err("model needs at least one [[layers]] entry".into());
        };
        if last.outputs != self.dataset.classes {
            return Err(format!(
                "last layer outputs {} != dataset.classes {}",
                last.outputs, self.dataset.classes
            ));
        }
        if self.dataset.features < self.dataset.classes {
            return Err("dataset needs features >= classes (one band per class)".into());
        }
        if !(0.0..=0.5).contains(&self.dataset.jitter) {
            return Err(format!("dataset.jitter {} outside 0..=0.5", self.dataset.jitter));
        }
        Ok(())
    }

    /// Synthetic trial `t`: `(label, features)` as a pure function of
    /// `(seed, t)` — any shard can materialize any trial independently.
    /// Features sit near 0.75 inside the label's band and near 0.15
    /// outside, jittered by `dataset.jitter`.
    pub fn trial_input(&self, t: u64) -> (usize, Vec<f64>) {
        let d = &self.dataset;
        let label = (t % d.classes as u64) as usize;
        let mut rng = SplitMix64::for_stream(self.seed ^ DATA_SALT, t);
        let xs = (0..d.features)
            .map(|i| {
                let base = if d.feature_tag(i) == label { 0.75 } else { 0.15 };
                (base + d.jitter * (2.0 * rng.next_f64() - 1.0)).clamp(0.0, 1.0)
            })
            .collect();
        (label, xs)
    }

    /// Prototype-structured weights for layer `l`, drawn from the
    /// layer's own counter stream: unit `j` prefers inputs tagged with
    /// its class (`j % classes`), so the quantized model actually
    /// classifies the synthetic set — reproducible from the seed alone,
    /// no external weight files.
    pub fn layer_weights(&self, l: usize) -> Tensor {
        let spec = self.layers[l];
        let classes = self.dataset.classes;
        let mut rng = SplitMix64::for_stream(self.seed ^ WEIGHT_SALT, l as u64);
        Tensor::from_fn(spec.outputs, spec.inputs, |j, i| {
            let tag_in = if l == 0 { self.dataset.feature_tag(i) } else { i % classes };
            let u = rng.next_f64();
            if tag_in == j % classes {
                0.5 + 0.5 * u
            } else {
                -0.25 + 0.35 * u
            }
        })
    }

    /// Build the executable model: generate + quantize weights and
    /// calibrate the inter-layer activation quantizers over the first
    /// `trials` trials of the exact-integer pipeline.
    pub fn build(&self, trials: u32) -> Model {
        let bits = self.bits;
        let layers: Vec<DenseLayer> = (0..self.layers.len())
            .map(|l| DenseLayer {
                w: QuantMatrix::from_tensor(&self.layer_weights(l), bits),
                relu: self.layers[l].relu,
            })
            .collect();
        let in_q = QParams::symmetric(1.0, bits);
        // Boundary-by-boundary static calibration, carrying every trial's
        // activations forward so the whole pass is O(layers x trials):
        // with quantizers 0..l fixed, the exact pipeline's layer-l
        // pre-quantization activations give boundary l's symmetric range.
        // The final layer feeds argmax directly, so it needs no quantizer.
        // Deterministic in (spec, trials).
        let mut xs: Vec<QuantVec> = (0..u64::from(trials.max(1)))
            .map(|t| QuantVec::from_f64(&self.trial_input(t).1, in_q))
            .collect();
        let mut act_q: Vec<QParams> = Vec::with_capacity(layers.len().saturating_sub(1));
        for l in 0..layers.len() - 1 {
            let accs: Vec<Vec<i64>> = xs.iter().map(|x| layers[l].forward_exact(x)).collect();
            let unit = scale_of(&layers, in_q, &act_q, l);
            let mut max_abs = 0.0f64;
            for acc in &accs {
                for &a in acc {
                    max_abs = max_abs.max(post_act(a as f64 * unit, layers[l].relu).abs());
                }
            }
            act_q.push(QParams::symmetric(max_abs, bits));
            xs = accs
                .iter()
                .map(|acc| requantize(acc, &layers[l], unit, act_q[l]))
                .collect();
        }
        Model { spec: self.clone(), layers, in_q, act_q }
    }
}

/// ReLU when the layer asks for it.
fn post_act(y: f64, relu: bool) -> f64 {
    if relu {
        y.max(0.0)
    } else {
        y
    }
}

/// Real value of one integer accumulator unit of layer `l`:
/// `w_scale(l) * in_scale(l)`.
fn scale_of(layers: &[DenseLayer], in_q: QParams, act_q: &[QParams], l: usize) -> f64 {
    let in_scale = if l == 0 { in_q.scale } else { act_q[l - 1].scale };
    layers[l].w.qp.scale * in_scale
}

/// Accumulators -> next layer's quantized activations (shared by the
/// exact and analog paths, so noise is the only difference between them).
fn requantize(acc: &[i64], layer: &DenseLayer, unit: f64, out_q: QParams) -> QuantVec {
    let q = acc
        .iter()
        .map(|&a| out_q.quantize(post_act(a as f64 * unit, layer.relu)))
        .collect();
    QuantVec { q, qp: out_q }
}

/// Argmax with first-wins ties — the deterministic top-1 rule both the
/// exact and the noisy path use.
fn argmax_i64(acc: &[i64]) -> usize {
    let mut best = 0;
    for (j, &a) in acc.iter().enumerate().skip(1) {
        if a > acc[best] {
            best = j;
        }
    }
    best
}

/// A built model: quantized layers plus the calibrated quantizer chain.
#[derive(Debug, Clone)]
pub struct Model {
    /// The spec the model was built from.
    pub spec: ModelSpec,
    /// Quantized dense layers, input to output.
    pub layers: Vec<DenseLayer>,
    /// Input quantizer (unit range onto the magnitude grid).
    pub in_q: QParams,
    /// Inter-layer activation quantizers from static calibration — one
    /// per layer boundary (`layers.len() - 1` entries; the final layer
    /// feeds argmax directly and needs none).
    pub act_q: Vec<QParams>,
}

impl Model {
    /// 4-bit array words per operand.
    pub fn words(&self) -> u32 {
        self.in_q.words()
    }

    /// Analog MAC operations per inference trial.
    pub fn ops_per_trial(&self) -> u64 {
        self.layers.iter().map(|l| l.ops(self.words())).sum()
    }

    /// Global-item offset of layer `l` within one trial's op stream.
    pub fn layer_item_offset(&self, l: usize) -> u64 {
        self.layers[..l].iter().map(|x| x.ops(self.words())).sum()
    }

    /// Quantize a raw feature vector with the input quantizer.
    pub fn quantize_input(&self, xs: &[f64]) -> QuantVec {
        QuantVec::from_f64(xs, self.in_q)
    }

    /// Real value of one accumulator unit of layer `l`.
    pub fn acc_unit(&self, l: usize) -> f64 {
        scale_of(&self.layers, self.in_q, &self.act_q, l)
    }

    /// Layer `l` accumulators -> the next layer's quantized input.
    pub fn activate(&self, l: usize, acc: &[i64]) -> QuantVec {
        requantize(acc, &self.layers[l], self.acc_unit(l), self.act_q[l])
    }

    /// Final-layer accumulators -> real output scores.
    pub fn output_real(&self, acc: &[i64]) -> Vec<f64> {
        let unit = self.acc_unit(self.layers.len() - 1);
        acc.iter().map(|&a| a as f64 * unit).collect()
    }

    /// Deterministic top-1 over final-layer accumulators.
    pub fn predict(&self, acc: &[i64]) -> usize {
        argmax_i64(acc)
    }

    /// Exact integer forward pass: `(top-1 class, real output scores)` —
    /// the reference the noisy analog execution is measured against.
    pub fn forward_exact(&self, x0: &QuantVec) -> (usize, Vec<f64>) {
        let mut x = x0.clone();
        let last = self.layers.len() - 1;
        for l in 0..last {
            let acc = self.layers[l].forward_exact(&x);
            x = self.activate(l, &acc);
        }
        let acc = self.layers[last].forward_exact(&x);
        (self.predict(&acc), self.output_real(&acc))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixture_parses_and_validates() {
        let spec = ModelSpec::fixture();
        assert_eq!(spec.name, "fixture-mlp");
        assert_eq!(spec.bits, 4);
        assert_eq!(spec.layers.len(), 2);
        assert!(spec.layers[0].relu && !spec.layers[1].relu);
        assert!(spec.validate().is_ok());
    }

    #[test]
    fn validation_rejects_bad_specs() {
        let mut s = ModelSpec::fixture();
        s.bits = 6;
        assert!(s.validate().is_err());
        let mut s = ModelSpec::fixture();
        s.layers[0].outputs = 7; // breaks the chain to layer 1
        assert!(s.validate().is_err());
        let mut s = ModelSpec::fixture();
        s.dataset.features = 12; // != first layer inputs
        assert!(s.validate().is_err());
        let mut s = ModelSpec::fixture();
        s.dataset.jitter = 0.9;
        assert!(s.validate().is_err());
        let mut s = ModelSpec::fixture();
        s.trials = 0;
        assert!(s.validate().is_err());
        assert!(ModelSpec::parse("name = \"x\"\n").is_err()); // no dataset/layers
    }

    #[test]
    fn trials_are_pure_functions_of_seed_and_index() {
        let spec = ModelSpec::fixture();
        let (l1, x1) = spec.trial_input(13);
        let (l2, x2) = spec.trial_input(13);
        assert_eq!((l1, &x1), (l2, &x2));
        assert_ne!(x1, spec.trial_input(14).1);
        assert_eq!(l1, 13 % 4);
        assert!(x1.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let mut other = spec.clone();
        other.seed = 1;
        assert_ne!(x1, other.trial_input(13).1);
    }

    #[test]
    fn build_is_deterministic_and_classifies_the_synthetic_set() {
        let spec = ModelSpec::fixture();
        let a = spec.build(16);
        let b = spec.build(16);
        assert_eq!(a.layers[0].w, b.layers[0].w);
        assert_eq!(a.act_q, b.act_q);
        assert_eq!(a.ops_per_trial(), 8 * 16 + 4 * 8);
        assert_eq!(a.layer_item_offset(1), 128);
        // the exact pipeline separates the bands well: >= 75% top-1
        let correct = (0..16u64)
            .filter(|&t| {
                let (label, xs) = spec.trial_input(t);
                a.forward_exact(&a.quantize_input(&xs)).0 == label
            })
            .count();
        assert!(correct >= 12, "exact fixture accuracy {correct}/16");
    }

    #[test]
    fn eight_bit_operands_quadruple_the_op_count() {
        let mut spec = ModelSpec::fixture();
        spec.bits = 8;
        let m = spec.build(4);
        assert_eq!(m.words(), 2);
        assert_eq!(m.ops_per_trial(), (8 * 16 + 4 * 8) * 4);
    }
}
