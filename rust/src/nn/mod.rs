//! Noisy neural-network inference on the analog in-SRAM MAC
//! (`smart infer`, DESIGN.md §10).
//!
//! The paper's pitch is that threshold-voltage suppression makes the
//! analog 4×4-bit MAC accurate *enough for real workloads*; this
//! subsystem closes the loop by running fixed-point NN inference where
//! **every multiply-accumulate executes on the simulated noisy MAC**
//! instead of exact integer arithmetic. The pipeline:
//!
//! * [`Tensor`] + [`QParams`] — a minimal row-major `f64` tensor and
//!   symmetric per-layer quantization to the MAC's 4-bit operand width,
//!   with multi-bit operands split into 4-bit words exactly as the
//!   array stores them ([`nibble`], the `MacWord` convention);
//! * [`DenseLayer`] / [`ModelSpec`] — dense layers with ReLU/argmax,
//!   specified in a `configs/nn.toml`-style file; weights come from a
//!   seeded [`crate::montecarlo::SplitMix64`] stream so models are
//!   reproducible without external weight files, and a tiny fixture
//!   model is embedded ([`ModelSpec::fixture`]);
//! * [`Tiler`] — tiles each matrix–vector product into 4×4-bit MAC ops
//!   and drives them through the existing [`crate::mac::SimKernel`]
//!   block-execution path (scalar oracle and lockstep block kernel are
//!   bit-identical), drawing per-op mismatch from
//!   [`crate::montecarlo::MismatchSampler`]'s per-item counter streams;
//! * [`run_infer`] — a sharded campaign over N inference trials (one
//!   Monte-Carlo instance per trial) on a deterministic synthetic
//!   classification set, folding per-trial top-1 accuracy and output
//!   error through [`crate::metrics::OnlineStats`] in canonical trial
//!   order, and costing energy per inference through
//!   [`crate::energy::EnergyModel`].
//!
//! Determinism contract (DESIGN.md §10): per-op mismatch deviates are a
//! pure function of `(seed, global op index)`, per-trial results fold in
//! trial order, and every artifact number is canonicalized to the CSV
//! cell precision — so `smart infer` artifacts are **byte-identical for
//! any `--shards`/`--threads`/`--block`** and for either kernel. With
//! mismatch off (`--noise-off`) the offset-calibrated reconstruction
//! recovers every product exactly, so the noisy forward pass equals the
//! exact integer forward pass bit for bit.

mod eval;
mod layer;
mod model;
mod quant;
mod tensor;
mod tiler;

pub use eval::{infer_json, run_infer, run_infer_batch, InferOptions, InferReport, TrialRecord};
pub use layer::{DenseLayer, LayerSpec};
pub use model::{DatasetSpec, Model, ModelSpec};
pub use quant::{nibble, QParams, QuantMatrix, QuantVec};
pub use tensor::Tensor;
pub use tiler::{MatvecResult, Tiler};
