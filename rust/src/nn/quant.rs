//! Symmetric per-layer quantization to the MAC's operand width
//! (DESIGN.md §10).
//!
//! Weights and activations are quantized with a symmetric linear
//! quantizer: `q = round(x / scale)` clamped to `±(2^bits - 1)`. The
//! sign lives in the digital domain (the array stores magnitudes; signs
//! are applied when the coordinator accumulates reconstructed
//! products), and magnitudes wider than the array's 4-bit word are
//! split into 4-bit words exactly as [`crate::sram::MacWord`] stores
//! multi-bit operands — the product of two split operands recombines
//! with binary weights `16^(wa + wb)` ([`nibble`]).

use super::tensor::Tensor;

/// Symmetric linear quantization parameters for one layer.
///
/// ```
/// use smart_insram::nn::QParams;
/// let qp = QParams::symmetric(3.0, 4); // map [-3, 3] onto -15..=15
/// let q = qp.quantize(1.0);
/// assert!((qp.dequantize(q) - 1.0).abs() <= qp.scale / 2.0);
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QParams {
    /// Real value of one quantization step (> 0).
    pub scale: f64,
    /// Operand magnitude width in bits (4 or 8 — 1 or 2 array words).
    pub bits: u32,
}

impl QParams {
    /// Quantizer mapping `[-max_abs, max_abs]` onto the full magnitude
    /// range. A non-positive / non-finite `max_abs` (e.g. an all-zero
    /// calibration set) falls back to a unit range.
    pub fn symmetric(max_abs: f64, bits: u32) -> Self {
        assert!(bits == 4 || bits == 8, "operand width must be 4 or 8 bits, got {bits}");
        let q_max = f64::from((1u32 << bits) - 1);
        let scale = if max_abs.is_finite() && max_abs > 0.0 { max_abs / q_max } else { 1.0 / q_max };
        Self { scale, bits }
    }

    /// Largest representable magnitude (`2^bits - 1`).
    pub fn q_max(&self) -> i32 {
        ((1u32 << self.bits) - 1) as i32
    }

    /// 4-bit array words per operand (1 for 4-bit, 2 for 8-bit).
    pub fn words(&self) -> u32 {
        self.bits / 4
    }

    /// Quantize a real value to the signed grid (round to nearest,
    /// clamp to `±q_max`).
    pub fn quantize(&self, x: f64) -> i32 {
        let m = f64::from(self.q_max());
        (x / self.scale).round().clamp(-m, m) as i32
    }

    /// Real value of a quantized code.
    pub fn dequantize(&self, q: i32) -> f64 {
        f64::from(q) * self.scale
    }
}

/// 4-bit word `w` (LSB-first) of magnitude `mag` — the array-word split
/// of a multi-bit operand. `sum_w nibble(m, w) * 16^w == m`.
pub fn nibble(mag: u32, w: u32) -> u8 {
    ((mag >> (4 * w)) & 0xF) as u8
}

/// A quantized activation vector (signed codes + its quantizer).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantVec {
    /// Signed quantized codes, magnitude `<= qp.q_max()`.
    pub q: Vec<i32>,
    /// The quantizer the codes were produced with.
    pub qp: QParams,
}

impl QuantVec {
    /// Quantize a real vector.
    pub fn from_f64(xs: &[f64], qp: QParams) -> Self {
        Self { q: xs.iter().map(|&x| qp.quantize(x)).collect(), qp }
    }

    /// Number of elements.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    /// True when the vector has no elements.
    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }
}

/// A quantized weight matrix (row-major signed codes + its quantizer).
#[derive(Debug, Clone, PartialEq)]
pub struct QuantMatrix {
    /// Number of rows (output neurons).
    pub rows: usize,
    /// Number of columns (input features).
    pub cols: usize,
    /// Row-major signed quantized codes, magnitude `<= qp.q_max()`.
    pub q: Vec<i32>,
    /// The per-layer symmetric quantizer.
    pub qp: QParams,
}

impl QuantMatrix {
    /// Symmetric per-layer quantization of a weight tensor: one scale
    /// for the whole matrix, calibrated to its largest magnitude.
    pub fn from_tensor(t: &Tensor, bits: u32) -> Self {
        let qp = QParams::symmetric(t.max_abs(), bits);
        let mut q = Vec::with_capacity(t.rows().saturating_mul(t.cols()));
        for r in 0..t.rows() {
            for c in 0..t.cols() {
                q.push(qp.quantize(t.get(r, c)));
            }
        }
        Self { rows: t.rows(), cols: t.cols(), q, qp }
    }

    /// Quantized code at `(row, col)`.
    pub fn at(&self, row: usize, col: usize) -> i32 {
        assert!(row < self.rows && col < self.cols, "index ({row}, {col}) out of range");
        self.q[row * self.cols + col]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_error_bounded_by_half_step() {
        for bits in [4u32, 8] {
            let qp = QParams::symmetric(2.5, bits);
            for k in -100..=100 {
                let x = f64::from(k) * 0.025; // spans [-2.5, 2.5]
                let err = (qp.dequantize(qp.quantize(x)) - x).abs();
                assert!(err <= qp.scale / 2.0 + 1e-12, "bits={bits} x={x}: err {err}");
            }
        }
    }

    #[test]
    fn quantize_clamps_and_is_symmetric() {
        let qp = QParams::symmetric(1.0, 4);
        assert_eq!(qp.quantize(10.0), 15);
        assert_eq!(qp.quantize(-10.0), -15);
        assert_eq!(qp.quantize(0.0), 0);
        assert_eq!(qp.quantize(-0.5), -qp.quantize(0.5));
        assert_eq!(qp.q_max(), 15);
        assert_eq!(QParams::symmetric(1.0, 8).q_max(), 255);
    }

    #[test]
    fn degenerate_range_falls_back_to_unit() {
        let qp = QParams::symmetric(0.0, 4);
        assert!(qp.scale > 0.0);
        assert_eq!(qp.quantize(1.0), 15);
    }

    #[test]
    fn nibbles_recombine_to_the_magnitude() {
        for mag in [0u32, 1, 15, 16, 0x5A, 200, 255] {
            let lo = u32::from(nibble(mag, 0));
            let hi = u32::from(nibble(mag, 1));
            assert_eq!(lo + 16 * hi, mag, "mag={mag}");
            assert!(lo < 16 && hi < 16);
        }
    }

    #[test]
    fn matrix_quantization_preserves_shape_and_scale() {
        let t = Tensor::from_fn(2, 3, |r, c| (r as f64 - 1.0) * (c as f64 + 1.0));
        let m = QuantMatrix::from_tensor(&t, 4);
        assert_eq!((m.rows, m.cols), (2, 3));
        // largest magnitude maps to the full code
        assert_eq!(m.at(0, 2), -15);
        assert_eq!(m.at(1, 0), 0);
        assert!((m.qp.scale - 3.0 / 15.0).abs() < 1e-12);
    }
}
