//! Minimal row-major `f64` tensor — just enough linear algebra for the
//! NN workload's weight matrices and reference math (DESIGN.md §10).

/// A dense row-major 2-D tensor of `f64` values.
///
/// ```
/// use smart_insram::nn::Tensor;
/// let t = Tensor::from_fn(2, 3, |r, c| (r * 3 + c) as f64);
/// assert_eq!(t.get(1, 2), 5.0);
/// assert_eq!(t.matvec(&[1.0, 0.0, 1.0]), vec![2.0, 7.0]);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Tensor {
    /// All-zero tensor of shape `(rows, cols)`.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Tensor filled by `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut t = Self::zeros(rows, cols);
        for r in 0..rows {
            for c in 0..cols {
                // lint:allow(L3): row-major index bounded by the zeros() allocation
                t.data[r * cols + c] = f(r, c);
            }
        }
        t
    }

    /// Number of rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Number of columns.
    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Element at `(row, col)`.
    pub fn get(&self, row: usize, col: usize) -> f64 {
        assert!(row < self.rows && col < self.cols, "index ({row}, {col}) out of range");
        self.data[row * self.cols + col]
    }

    /// Set element at `(row, col)`.
    pub fn set(&mut self, row: usize, col: usize, v: f64) {
        assert!(row < self.rows && col < self.cols, "index ({row}, {col}) out of range");
        self.data[row * self.cols + col] = v;
    }

    /// Row `row` as a slice.
    pub fn row(&self, row: usize) -> &[f64] {
        assert!(row < self.rows, "row {row} out of range");
        &self.data[row * self.cols..(row + 1) * self.cols]
    }

    /// Largest absolute element (0 for an empty tensor) — the symmetric
    /// quantizer's calibration statistic.
    pub fn max_abs(&self) -> f64 {
        // lint:allow(D2): max() fold is order-insensitive — no rounding accumulation
        self.data.iter().fold(0.0f64, |m, &v| m.max(v.abs()))
    }

    /// Matrix–vector product `self * x` in exact `f64` arithmetic — the
    /// floating-point reference the quantized pipeline approximates.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cols, "matvec shape mismatch");
        (0..self.rows)
            .map(|r| self.row(r).iter().zip(x).map(|(&w, &v)| w * v).sum())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_and_access_roundtrip() {
        let mut t = Tensor::zeros(3, 2);
        assert_eq!((t.rows(), t.cols()), (3, 2));
        t.set(2, 1, 4.5);
        assert_eq!(t.get(2, 1), 4.5);
        assert_eq!(t.row(2), &[0.0, 4.5]);
        assert_eq!(t.max_abs(), 4.5);
    }

    #[test]
    fn matvec_matches_hand_computation() {
        let t = Tensor::from_fn(2, 3, |r, c| (r * 3 + c + 1) as f64);
        // [[1 2 3], [4 5 6]] * [1, -1, 2] = [5, 11]
        assert_eq!(t.matvec(&[1.0, -1.0, 2.0]), vec![5.0, 11.0]);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn out_of_range_access_panics() {
        Tensor::zeros(1, 1).get(0, 1);
    }
}
