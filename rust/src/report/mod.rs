//! Report emission: the paper's tables/figures as markdown and CSV.

use std::fmt::Write as _;

use crate::coordinator::CampaignReport;
use crate::energy::{EnergyModel, LiteratureRow, OpCost, LITERATURE_ROWS};
use crate::mac::Variant;

/// One simulated row of Table 1.
#[derive(Debug, Clone)]
pub struct Table1Row {
    /// Design label (Table 1 row name).
    pub label: String,
    /// Technology node (nm).
    pub tech_nm: u32,
    /// Supply voltage (V).
    pub supply: f64,
    /// MAC energy (pJ).
    pub energy_pj: f64,
    /// Accuracy figure (STD.V — normalized output sigma).
    pub sigma: f64,
    /// Operating frequency (MHz).
    pub freq_mhz: f64,
}

impl Table1Row {
    /// Build a row from a variant's simulated cost and accuracy.
    pub fn new(variant: Variant, cost: &OpCost, sigma: f64, supply: f64) -> Self {
        Self {
            label: variant.name().to_string(),
            tech_nm: 65,
            supply,
            energy_pj: cost.energy * 1e12,
            sigma,
            freq_mhz: cost.frequency / 1e6,
        }
    }
}

/// Render Table 1 (simulated rows + quoted literature rows) as markdown.
pub fn table1_markdown(rows: &[Table1Row], lit: &[LiteratureRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "| design | tech (nm) | supply (V) | MAC energy (pJ) | accuracy (STD.V) | frequency (MHz) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {:.1} | {:.3} | {:.4} | {:.0} |",
            r.label, r.tech_nm, r.supply, r.energy_pj, r.sigma, r.freq_mhz
        );
    }
    for l in lit {
        let acc = l.accuracy_std.map_or("/".to_string(), |a| format!("{a:.3}"));
        let _ = writeln!(
            s,
            "| {} | {} | {:.1} | {:.3} | {} | {} |",
            l.label, l.tech_nm, l.supply, l.mac_energy_pj, acc, l.freq_mhz
        );
    }
    s
}

/// Standard Table 1 pipeline: simulate the three head-to-head variants.
pub fn build_table1(
    params: &crate::params::Params,
    sigmas: &[(Variant, f64)],
    model: &EnergyModel,
) -> String {
    let rows: Vec<Table1Row> = sigmas
        .iter()
        .map(|&(v, sigma)| {
            let cost = crate::energy::nominal_cost(params, v, model);
            Table1Row::new(v, &cost, sigma, v.config(params).supply)
        })
        .collect();
    table1_markdown(&rows, &LITERATURE_ROWS)
}

/// Render a campaign's MC histogram + stats (Fig. 8/9 panel) as text.
pub fn mc_panel(title: &str, r: &CampaignReport) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "## {title}");
    let ci = r
        .sigma_ci
        .map(|(lo, hi)| format!(" (95% CI [{:.2}, {:.2}])", lo * 1e3, hi * 1e3))
        .unwrap_or_default();
    let _ = writeln!(
        s,
        "n={} mean={:.1} mV sigma={:.2} mV{ci} sigma/FS={:.4} BER={:.4} faults={:.4}",
        r.rows,
        r.raw_vmult.mean() * 1e3,
        r.raw_vmult.std_dev() * 1e3,
        r.accuracy.sigma_norm,
        r.accuracy.ber,
        r.accuracy.fault_rate,
    );
    let _ = writeln!(s, "V_mult histogram [0, {:.0} mV):", r.full_scale * 1.25 * 1e3);
    let _ = writeln!(s, "{}", r.hist.sparkline());
    if r.hist.non_finite() > 0 {
        let _ = writeln!(
            s,
            "warning: {} non-finite sample(s) excluded from the bins",
            r.hist.non_finite()
        );
    }
    s
}

/// Canonical JSON encoding of a finished Monte-Carlo campaign — the
/// `mc.json` artifact `smart mc --json` writes and the byte-identical
/// body `smart serve` answers `POST /v1/mc` with (DESIGN.md §11).
///
/// Only the spec's *identity* fields appear (variant, workload, n_mc,
/// seed, corner, kernel): `--shards`/`--threads`/`--block` are pure
/// performance knobs under the bit-identical-aggregates contract
/// (DESIGN.md §4), so they must never change the bytes. The kernel tier
/// IS identity — `--kernel fast` is tolerance-bounded, not bit-identical
/// (DESIGN.md §13) — so it is recorded. Wall-clock and throughput are
/// deliberately absent for the same reason, and every float is
/// canonicalized through [`canon`].
pub fn mc_json(spec: &crate::coordinator::CampaignSpec, r: &CampaignReport) -> String {
    use crate::util::json::{to_string_pretty, Value};
    use std::collections::BTreeMap;
    let mut root = BTreeMap::new();
    let mut put = |k: &str, v: Value| {
        root.insert(k.to_string(), v);
    };
    put("variant", Value::Str(spec.variant.token().to_string()));
    put("workload", spec.workload.to_value());
    put("n_mc", Value::Num(f64::from(spec.n_mc)));
    put("seed", Value::Num(spec.seed as f64));
    put("corner", Value::Str(spec.corner.name().to_string()));
    put("kernel", Value::Str(spec.kernel.token().to_string()));
    put("rows", Value::Num(r.rows as f64));
    put("full_scale", Value::Num(canon(r.full_scale)));
    put("mean_v", Value::Num(canon(r.raw_vmult.mean())));
    put("sigma_v", Value::Num(canon(r.raw_vmult.std_dev())));
    put(
        "sigma_ci",
        match r.sigma_ci {
            Some((lo, hi)) => Value::Arr(vec![Value::Num(canon(lo)), Value::Num(canon(hi))]),
            None => Value::Null,
        },
    );
    put("sigma_norm", Value::Num(canon(r.accuracy.sigma_norm)));
    put("rms_norm", Value::Num(canon(r.accuracy.rms_norm)));
    put("snr_db", Value::Num(canon(r.accuracy.snr_db)));
    put("ber", Value::Num(canon(r.accuracy.ber)));
    put("fault_rate", Value::Num(canon(r.accuracy.fault_rate)));
    put("energy_mean", Value::Num(canon(r.energy.mean())));
    let (lo, hi) = r.hist.range();
    let mut hist = BTreeMap::new();
    hist.insert("lo".to_string(), Value::Num(canon(lo)));
    hist.insert("hi".to_string(), Value::Num(canon(hi)));
    hist.insert(
        "counts".to_string(),
        Value::Arr(r.hist.counts().iter().map(|&c| Value::Num(c as f64)).collect()),
    );
    hist.insert("non_finite".to_string(), Value::Num(r.hist.non_finite() as f64));
    put("hist", Value::Obj(hist));
    let mut text = to_string_pretty(&Value::Obj(root));
    text.push('\n');
    text
}

/// Format one CSV numeric cell: finite values as `{:.6e}`, non-finite as
/// an **empty cell** — the same "value absent" sentinel the JSON writer
/// uses (`crate::util::json` emits `null` for NaN/inf), so the two
/// artifact formats always agree. A bare `NaN`/`inf` token would parse
/// differently (or not at all) in downstream tools.
pub fn csv_cell(v: f64) -> String {
    if v.is_finite() {
        format!("{:.6e}", canon_zero(v))
    } else {
        String::new()
    }
}

/// Normalize `-0.0` to `+0.0`. The two compare equal but render with
/// different signs (`-0.000000e0` vs `0.000000e0`), so without this two
/// bit-identical pipelines could still diverge *textually* in CSV/JSON
/// artifacts and cache keys. The single statement of the sign-of-zero
/// rule, applied by [`canon`], [`csv_cell`], and the
/// [`crate::util::json`] number writer.
pub fn canon_zero(v: f64) -> f64 {
    if v == 0.0 {
        0.0
    } else {
        v
    }
}

/// Round to the artifact precision — [`csv_cell`]'s `{:.6e}` format, 6
/// significant digits, with `-0.0` normalized to `0.0`
/// ([`canon_zero`]) — so CSV and JSON artifacts carry identical
/// values and checkpoint round-trips are byte-exact. The single
/// statement of the artifact precision, shared by the `dse`, `nn`, and
/// `serve` artifact/response writers.
pub fn canon(v: f64) -> f64 {
    if v == 0.0 {
        0.0
    } else if v.is_finite() {
        format!("{v:.6e}").parse().unwrap_or(v)
    } else {
        v
    }
}

/// CSV emitter for figure series: header + rows of (x, series..., value).
pub fn csv<H: AsRef<str>>(header: &[H], rows: &[Vec<f64>]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{}",
        header.iter().map(|h| h.as_ref()).collect::<Vec<_>>().join(",")
    );
    for row in rows {
        let _ = writeln!(
            s,
            "{}",
            row.iter().map(|v| csv_cell(*v)).collect::<Vec<_>>().join(",")
        );
    }
    s
}

/// Render a finished design-space sweep as a markdown panel: the full
/// grid with Pareto markers, then the front summary and artifact paths.
pub fn sweep_panel(r: &crate::dse::SweepResult) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "## DSE sweep '{}' — {} points ({} computed, {} resumed)",
        r.name,
        r.points.len(),
        r.computed,
        r.resumed
    );
    let _ = writeln!(
        s,
        "| variant | vdd (V) | v_bulk (V) | bits | corner | energy (pJ) | sigma/FS | BER | front |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|");
    for (p, &front) in r.points.iter().zip(&r.pareto) {
        let _ = writeln!(
            s,
            "| {} | {:.2} | {:.2} | {} | {} | {:.3} | {:.4} | {:.4} | {} |",
            p.point.variant.token(),
            p.point.vdd,
            p.point.v_bulk,
            p.point.bits,
            p.point.corner.name(),
            p.energy_pj,
            p.sigma_norm,
            p.ber,
            if front { "*" } else { "" }
        );
    }
    let n_front = r.pareto.iter().filter(|&&f| f).count();
    let _ = writeln!(s, "pareto front: {} / {} points", n_front, r.points.len());
    let _ = writeln!(
        s,
        "artifacts: {} , {}",
        r.csv_path.display(),
        r.json_path.display()
    );
    s
}

/// Render a finished noisy-inference campaign (`smart infer`) as a
/// markdown panel: accuracy triplet, noise figures, and the energy cost.
pub fn infer_panel(r: &crate::nn::InferReport) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "## smart infer '{}' — {} on {} kernel, {} trials",
        r.name,
        r.variant.name(),
        r.kernel,
        r.trials
    );
    let _ = writeln!(
        s,
        "top-1: ideal {:.1}% | noisy {:.1}% | delta {:+.1} pp | noisy-vs-ideal agreement {:.1}%",
        r.ideal_accuracy * 100.0,
        r.noisy_accuracy * 100.0,
        r.accuracy_delta() * 100.0,
        r.agreement * 100.0
    );
    let _ = writeln!(
        s,
        "output err mean {:.4} max {:.4} | fault rate {:.2e} | {} MACs/inference",
        r.out_err.mean(),
        r.out_err.max(),
        r.fault_rate,
        r.macs_per_inference
    );
    let _ = writeln!(
        s,
        "energy: {:.3} pJ/MAC, {:.2} pJ/inference @ {:.0} MHz",
        r.energy_per_mac_pj, r.energy_per_inference_pj, r.freq_mhz
    );
    if let (Some(csv), Some(json)) = (&r.csv_path, &r.json_path) {
        let _ = writeln!(s, "artifacts: {} , {}", csv.display(), json.display());
    }
    s
}

/// Render a lint run (`smart lint`) as a markdown panel: unsuppressed
/// findings as a table (these fail the build), then a per-rule tally of
/// the reasoned suppressions so the allowlist stays visible.
pub fn lint_panel(r: &crate::lint::LintReport) -> String {
    let mut s = String::new();
    let open: Vec<&crate::lint::Finding> = r.unsuppressed().collect();
    let suppressed = r.findings.len() - open.len();
    let _ = writeln!(
        s,
        "## smart lint — {} file(s), {} finding(s) ({} unsuppressed, {} suppressed)",
        r.files,
        r.findings.len(),
        open.len(),
        suppressed
    );
    if open.is_empty() {
        let _ = writeln!(
            s,
            "clean: determinism invariants D1-D7 and structural invariants L1-L5 \
             hold (DESIGN.md §12, §16)"
        );
    } else {
        let _ = writeln!(s, "| rule | location | note |");
        let _ = writeln!(s, "|---|---|---|");
        for f in &open {
            let _ = writeln!(s, "| {} | {} | {} |", f.rule.id(), f.location(), f.note);
        }
    }
    for rule in crate::lint::RULES {
        let n = r.findings.iter().filter(|f| f.rule == rule && f.suppressed.is_some()).count();
        if n > 0 {
            let _ = writeln!(s, "suppressed {}: {} ({})", rule.id(), n, rule.summary());
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;
    use crate::params::Params;

    #[test]
    fn table1_contains_all_designs() {
        let p = Params::default();
        let t = build_table1(
            &p,
            &[(Variant::Smart, 0.01), (Variant::Aid, 0.03), (Variant::Imac, 0.1)],
            &EnergyModel::default(),
        );
        let needles =
            ["SMART", "AID [10]", "IMAC [9]", "[14] (lit.)", "[21] (lit.)", "1.300", "3.500"];
        for needle in needles {
            assert!(t.contains(needle), "missing {needle} in:\n{t}");
        }
        assert_eq!(t.lines().count(), 2 + 3 + 2);
    }

    #[test]
    fn csv_formats_rows() {
        let out = csv(&["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.5]]);
        let mut lines = out.lines();
        assert_eq!(lines.next().unwrap(), "x,y");
        assert!(lines.next().unwrap().starts_with("1.0"));
    }

    #[test]
    fn infer_panel_lists_the_accuracy_triplet() {
        let mut out_err = crate::metrics::OnlineStats::new();
        out_err.push(0.01);
        let r = crate::nn::InferReport {
            name: "fixture-mlp".to_string(),
            variant: Variant::Smart,
            kernel: "block",
            trials: 8,
            macs_per_inference: 160,
            ideal_accuracy: 1.0,
            noisy_accuracy: 0.875,
            agreement: 0.875,
            out_err,
            fault_rate: 0.0,
            energy_per_mac_pj: 0.783,
            energy_per_inference_pj: 125.3,
            freq_mhz: 250.0,
            records: Vec::new(),
            csv_path: None,
            json_path: None,
            wall: std::time::Duration::from_millis(5),
        };
        let s = infer_panel(&r);
        for needle in ["fixture-mlp", "SMART", "ideal 100.0%", "noisy 87.5%", "+12.5 pp", "160 MACs"]
        {
            assert!(s.contains(needle), "missing {needle} in:\n{s}");
        }
    }

    #[test]
    fn negative_zero_is_canonicalized_everywhere() {
        // regression: -0.0 rendered as "-0.000000e0", so bit-identical
        // pipelines could diverge textually on sign-of-zero
        assert_eq!(canon(-0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(canon_zero(-0.0).to_bits(), 0.0f64.to_bits());
        assert_eq!(canon_zero(-1.5), -1.5);
        assert_eq!(csv_cell(-0.0), "0.000000e0");
        assert_eq!(csv_cell(-0.0), csv_cell(0.0));
        // the CSV emitter and the JSON writer agree
        let out = csv(&["x"], &[vec![-0.0]]);
        assert_eq!(out.lines().nth(1).unwrap(), "0.000000e0");
        let json = crate::util::json::to_string_pretty(&crate::util::json::Value::Num(-0.0));
        assert_eq!(json, "0");
        // negative non-zero values keep their sign
        assert_eq!(csv_cell(-1.0), "-1.000000e0");
        assert_eq!(canon(-1.0), -1.0);
    }

    #[test]
    fn mc_json_is_canonical_and_excludes_perf_knobs() {
        use crate::coordinator::{run_campaign, Backend, CampaignSpec};
        let p = Params::default();
        let mut spec = CampaignSpec::paper_fig8(Variant::Smart);
        spec.n_mc = 16;
        let r = run_campaign(&p, &spec, Backend::Native, None).unwrap();
        let a = mc_json(&spec, &r);
        // perf knobs never appear in the canonical bytes
        let mut knobbed = spec.clone();
        knobbed.workers = 3;
        knobbed.shards = 7;
        knobbed.block = 5;
        let r2 = run_campaign(&p, &knobbed, Backend::Native, None).unwrap();
        let b = mc_json(&knobbed, &r2);
        assert_eq!(a, b, "perf knobs leaked into mc.json");
        for needle in [
            "\"variant\"",
            "\"workload\"",
            "\"hist\"",
            "\"non_finite\"",
            "\"sigma_norm\"",
            "\"kernel\": \"block\"",
        ] {
            assert!(a.contains(needle), "missing {needle} in {a}");
        }
        assert!(!a.contains("\"shards\""));
        assert!(crate::util::json::parse(&a).is_ok());
        assert!(a.ends_with('\n'));
    }

    #[test]
    fn lint_panel_tables_open_findings_and_tallies_suppressions() {
        use crate::lint::{Finding, LintReport, Rule};
        let mk = |rule, line, suppressed: Option<&str>| Finding {
            rule,
            path: "rust/src/x.rs".to_string(),
            line,
            note: "note".to_string(),
            suppressed: suppressed.map(str::to_string),
        };
        let r = LintReport {
            findings: vec![
                mk(Rule::PanicPath, 3, None),
                mk(Rule::WallClock, 9, Some("console-only")),
            ],
            files: 1,
        };
        let s = lint_panel(&r);
        assert!(s.contains("1 unsuppressed, 1 suppressed"), "{s}");
        assert!(s.contains("| D4 | rust/src/x.rs:3 |"), "{s}");
        assert!(s.contains("suppressed D6: 1"), "{s}");
        let clean = lint_panel(&LintReport { findings: vec![], files: 2 });
        assert!(clean.contains("clean"), "{clean}");
    }

    #[test]
    fn csv_non_finite_cells_are_empty() {
        // agreement with the JSON writer: both emit a "value absent"
        // sentinel for non-finite numbers, never a bare NaN/inf token
        assert_eq!(csv_cell(f64::NAN), "");
        assert_eq!(csv_cell(f64::INFINITY), "");
        assert_eq!(csv_cell(f64::NEG_INFINITY), "");
        assert_eq!(csv_cell(1.0), "1.000000e0");
        let out = csv(&["x", "y"], &[vec![f64::NAN, 2.0], vec![3.0, f64::INFINITY]]);
        let mut lines = out.lines();
        assert_eq!(lines.next().unwrap(), "x,y");
        assert_eq!(lines.next().unwrap(), ",2.000000e0");
        assert_eq!(lines.next().unwrap(), "3.000000e0,");
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let json = crate::util::json::to_string_pretty(&crate::util::json::Value::Num(bad));
            assert_eq!(json, "null");
            assert_eq!(csv_cell(bad), "");
        }
    }
}
