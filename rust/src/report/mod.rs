//! Report emission: the paper's tables/figures as markdown and CSV.

use std::fmt::Write as _;

use crate::coordinator::CampaignReport;
use crate::energy::{EnergyModel, LiteratureRow, OpCost, LITERATURE_ROWS};
use crate::mac::Variant;

/// One simulated row of Table 1.
#[derive(Debug, Clone)]
pub struct Table1Row {
    pub label: String,
    pub tech_nm: u32,
    pub supply: f64,
    pub energy_pj: f64,
    pub sigma: f64,
    pub freq_mhz: f64,
}

impl Table1Row {
    pub fn new(variant: Variant, cost: &OpCost, sigma: f64, supply: f64) -> Self {
        Self {
            label: variant.name().to_string(),
            tech_nm: 65,
            supply,
            energy_pj: cost.energy * 1e12,
            sigma,
            freq_mhz: cost.frequency / 1e6,
        }
    }
}

/// Render Table 1 (simulated rows + quoted literature rows) as markdown.
pub fn table1_markdown(rows: &[Table1Row], lit: &[LiteratureRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "| design | tech (nm) | supply (V) | MAC energy (pJ) | accuracy (STD.V) | frequency (MHz) |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {:.1} | {:.3} | {:.4} | {:.0} |",
            r.label, r.tech_nm, r.supply, r.energy_pj, r.sigma, r.freq_mhz
        );
    }
    for l in lit {
        let acc = l.accuracy_std.map_or("/".to_string(), |a| format!("{a:.3}"));
        let _ = writeln!(
            s,
            "| {} | {} | {:.1} | {:.3} | {} | {} |",
            l.label, l.tech_nm, l.supply, l.mac_energy_pj, acc, l.freq_mhz
        );
    }
    s
}

/// Standard Table 1 pipeline: simulate the three head-to-head variants.
pub fn build_table1(
    params: &crate::params::Params,
    sigmas: &[(Variant, f64)],
    model: &EnergyModel,
) -> String {
    let rows: Vec<Table1Row> = sigmas
        .iter()
        .map(|&(v, sigma)| {
            let cost = crate::energy::nominal_cost(params, v, model);
            Table1Row::new(v, &cost, sigma, v.config(params).supply)
        })
        .collect();
    table1_markdown(&rows, &LITERATURE_ROWS)
}

/// Render a campaign's MC histogram + stats (Fig. 8/9 panel) as text.
pub fn mc_panel(title: &str, r: &CampaignReport) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "## {title}");
    let ci = r
        .sigma_ci
        .map(|(lo, hi)| format!(" (95% CI [{:.2}, {:.2}])", lo * 1e3, hi * 1e3))
        .unwrap_or_default();
    let _ = writeln!(
        s,
        "n={} mean={:.1} mV sigma={:.2} mV{ci} sigma/FS={:.4} BER={:.4} faults={:.4}",
        r.rows,
        r.raw_vmult.mean() * 1e3,
        r.raw_vmult.std_dev() * 1e3,
        r.accuracy.sigma_norm,
        r.accuracy.ber,
        r.accuracy.fault_rate,
    );
    let _ = writeln!(s, "V_mult histogram [0, {:.0} mV):", r.full_scale * 1.25 * 1e3);
    let _ = writeln!(s, "{}", r.hist.sparkline());
    s
}

/// CSV emitter for figure series: header + rows of (x, series..., value).
pub fn csv<H: AsRef<str>>(header: &[H], rows: &[Vec<f64>]) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "{}",
        header.iter().map(|h| h.as_ref()).collect::<Vec<_>>().join(",")
    );
    for row in rows {
        let _ = writeln!(
            s,
            "{}",
            row.iter().map(|v| format!("{v:.6e}")).collect::<Vec<_>>().join(",")
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::energy::EnergyModel;
    use crate::params::Params;

    #[test]
    fn table1_contains_all_designs() {
        let p = Params::default();
        let t = build_table1(
            &p,
            &[(Variant::Smart, 0.01), (Variant::Aid, 0.03), (Variant::Imac, 0.1)],
            &EnergyModel::default(),
        );
        let needles =
            ["SMART", "AID [10]", "IMAC [9]", "[14] (lit.)", "[21] (lit.)", "1.300", "3.500"];
        for needle in needles {
            assert!(t.contains(needle), "missing {needle} in:\n{t}");
        }
        assert_eq!(t.lines().count(), 2 + 3 + 2);
    }

    #[test]
    fn csv_formats_rows() {
        let out = csv(&["x", "y"], &[vec![1.0, 2.0], vec![3.0, 4.5]]);
        let mut lines = out.lines();
        assert_eq!(lines.next().unwrap(), "x,y");
        assert!(lines.next().unwrap().starts_with("1.0"));
    }
}
