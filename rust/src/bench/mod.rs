//! Micro-benchmark harness (criterion is not available offline).
//!
//! `harness = false` benches link this: warmup + timed samples, robust
//! summary (median, mean, sigma, min), and a `Runner` that prints rows in
//! a criterion-like format. Wall-clock timing via `Instant`.

use std::time::{Duration, Instant};

/// Timing summary over the measured samples.
#[derive(Debug, Clone, Copy)]
pub struct Summary {
    /// Timed samples the summary is over.
    pub samples: usize,
    /// Mean sample time.
    pub mean: Duration,
    /// Median sample time.
    pub median: Duration,
    /// Standard deviation of the sample times.
    pub std_dev: Duration,
    /// Fastest sample.
    pub min: Duration,
    /// Slowest sample.
    pub max: Duration,
}

impl Summary {
    fn from_samples(mut xs: Vec<Duration>) -> Self {
        assert!(!xs.is_empty());
        xs.sort();
        let n = xs.len();
        let sum: Duration = xs.iter().sum();
        // lint:allow(D3): n = xs.len() is a CLI-bounded sample count, far below u32::MAX
        let mean = sum / n as u32;
        let mean_s = mean.as_secs_f64();
        let var = xs
            .iter()
            .map(|d| (d.as_secs_f64() - mean_s).powi(2))
            .sum::<f64>() // lint:allow(D2): variance over <=1e3 samples, display only
            / n as f64;
        Self {
            samples: n,
            mean,
            median: xs[n / 2],
            std_dev: Duration::from_secs_f64(var.sqrt()),
            min: xs[0],
            max: xs[n - 1],
        }
    }

    /// Throughput given the number of items processed per iteration.
    pub fn per_second(&self, items: u64) -> f64 {
        items as f64 / self.mean.as_secs_f64()
    }
}

/// Benchmark runner: fixed warmup iterations then timed samples.
pub struct Runner {
    /// Untimed warmup iterations before sampling.
    pub warmup: usize,
    /// Timed samples per bench.
    pub samples: usize,
}

impl Default for Runner {
    fn default() -> Self {
        Self { warmup: 2, samples: 10 }
    }
}

impl Runner {
    /// Low-iteration runner for slow end-to-end benches.
    pub fn quick() -> Self {
        Self { warmup: 1, samples: 5 }
    }

    /// Time `f` and print a criterion-style row. The closure's return
    /// value is passed through a black box so work is not optimized away.
    pub fn bench<T>(&self, name: &str, mut f: impl FnMut() -> T) -> Summary {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut xs = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            xs.push(t0.elapsed());
        }
        let s = Summary::from_samples(xs);
        println!(
            "{name:<44} time: [{:>10.3?} {:>10.3?} {:>10.3?}]  sigma {:.3?}",
            s.min, s.median, s.max, s.std_dev
        );
        s
    }
}

/// Format a number with engineering suffixes for report tables.
pub fn eng(x: f64) -> String {
    let ax = x.abs();
    let (scale, suffix) = if ax >= 1e9 {
        (1e-9, "G")
    } else if ax >= 1e6 {
        (1e-6, "M")
    } else if ax >= 1e3 {
        (1e-3, "k")
    } else if ax >= 1.0 || x == 0.0 {
        (1.0, "")
    } else if ax >= 1e-3 {
        (1e3, "m")
    } else if ax >= 1e-6 {
        (1e6, "u")
    } else if ax >= 1e-9 {
        (1e9, "n")
    } else if ax >= 1e-12 {
        (1e12, "p")
    } else {
        (1e15, "f")
    };
    format!("{:.3}{suffix}", x * scale)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_orders_and_averages() {
        let s = Summary::from_samples(vec![
            Duration::from_millis(1),
            Duration::from_millis(3),
            Duration::from_millis(2),
        ]);
        assert_eq!(s.samples, 3);
        assert_eq!(s.min, Duration::from_millis(1));
        assert_eq!(s.max, Duration::from_millis(3));
        assert_eq!(s.median, Duration::from_millis(2));
        assert_eq!(s.mean, Duration::from_millis(2));
    }

    #[test]
    fn runner_executes_expected_iterations() {
        let mut count = 0;
        let r = Runner { warmup: 3, samples: 7 };
        let s = r.bench("test", || count += 1);
        assert_eq!(count, 10);
        assert_eq!(s.samples, 7);
        assert!(s.per_second(100) > 0.0);
    }

    #[test]
    fn eng_suffixes() {
        assert_eq!(eng(0.783e-12), "783.000f");
        assert_eq!(eng(1.5e-12), "1.500p");
        assert_eq!(eng(250e6), "250.000M");
        assert_eq!(eng(1.5), "1.500");
        assert_eq!(eng(30e-15), "30.000f");
    }
}
