//! Quantiles + bootstrap confidence intervals for MC campaign reports:
//! a 1000-point sigma estimate deserves an error bar (Fig. 8/9).

use crate::montecarlo::SplitMix64;

/// Reservoir of raw samples with quantile and bootstrap queries.
/// Campaigns are at most ~10^6 rows here, so keeping the samples is fine;
/// for larger runs the Welford path remains the primary aggregate.
#[derive(Debug, Clone, Default)]
pub struct SampleSet {
    xs: Vec<f64>,
}

impl SampleSet {
    /// Empty reservoir.
    pub fn new() -> Self {
        Self::default()
    }

    /// Keep one sample.
    pub fn push(&mut self, x: f64) {
        self.xs.push(x);
    }

    /// Samples kept so far.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when no samples have been kept.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Linear-interpolated quantile, q in [0, 1]. Total over any input:
    /// non-finite samples sort to the ends under IEEE total order (NaN
    /// above +inf) instead of panicking the campaign that collected them.
    pub fn quantile(&self, q: f64) -> f64 {
        assert!(!self.xs.is_empty() && (0.0..=1.0).contains(&q));
        let mut s = self.xs.clone();
        s.sort_by(f64::total_cmp);
        let pos = q * (s.len() - 1) as f64;
        let lo = pos.floor() as usize;
        let hi = pos.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
        }
    }

    fn std_of(xs: &[f64]) -> f64 {
        let n = xs.len() as f64;
        // lint:allow(D2): folds a slice already in canonical sorted order
        let mean = xs.iter().sum::<f64>() / n;
        // lint:allow(D2): folds a slice already in canonical sorted order
        (xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n).sqrt()
    }

    /// Bootstrap percentile CI of the standard deviation: resample with
    /// replacement `n_boot` times, return (lo, hi) at the given level
    /// (e.g. 0.95). Seeded — reports are reproducible.
    pub fn bootstrap_std_ci(&self, n_boot: u32, level: f64, seed: u64) -> (f64, f64) {
        assert!(self.xs.len() >= 2 && (0.0..1.0).contains(&(1.0 - level)));
        let mut rng = SplitMix64::new(seed);
        let n = self.xs.len();
        let mut stds: Vec<f64> = (0..n_boot)
            .map(|_| {
                let resample: Vec<f64> =
                    (0..n).map(|_| self.xs[(rng.next_u64() % n as u64) as usize]).collect();
                Self::std_of(&resample)
            })
            .collect();
        // total order: a NaN resample statistic (possible when a campaign
        // pushed a non-finite sample) sorts last instead of panicking
        stds.sort_by(f64::total_cmp);
        let alpha = (1.0 - level) / 2.0;
        let idx = |q: f64| ((q * (n_boot - 1) as f64).round() as usize).min(n_boot as usize - 1);
        (stds[idx(alpha)], stds[idx(1.0 - alpha)])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uniform() -> SampleSet {
        let mut s = SampleSet::new();
        for i in 0..=100 {
            s.push(i as f64 / 100.0);
        }
        s
    }

    #[test]
    fn quantiles_of_uniform_grid() {
        let s = uniform();
        assert_eq!(s.quantile(0.0), 0.0);
        assert!((s.quantile(0.5) - 0.5).abs() < 1e-12);
        assert_eq!(s.quantile(1.0), 1.0);
        assert!((s.quantile(0.25) - 0.25).abs() < 1e-12);
    }

    #[test]
    fn bootstrap_ci_brackets_true_sigma() {
        // N(0, 2) samples via the library RNG
        let mut rng = SplitMix64::new(9);
        let mut s = SampleSet::new();
        for _ in 0..2000 {
            s.push(2.0 * rng.next_normal());
        }
        let (lo, hi) = s.bootstrap_std_ci(300, 0.95, 1);
        assert!(lo < 2.0 && 2.0 < hi, "CI [{lo}, {hi}] misses sigma=2");
        assert!(hi - lo < 0.4, "CI too wide: [{lo}, {hi}]");
    }

    #[test]
    fn bootstrap_is_seeded() {
        let s = uniform();
        assert_eq!(
            s.bootstrap_std_ci(100, 0.9, 7),
            s.bootstrap_std_ci(100, 0.9, 7)
        );
    }

    #[test]
    #[should_panic]
    fn quantile_rejects_empty() {
        SampleSet::new().quantile(0.5);
    }

    #[test]
    fn non_finite_samples_never_panic() {
        // one bad MC sample must not take down the whole campaign report
        let mut s = uniform();
        s.push(f64::NAN);
        s.push(f64::INFINITY);
        s.push(f64::NEG_INFINITY);
        assert_eq!(s.quantile(0.0), f64::NEG_INFINITY); // -inf sorts first
        assert!(s.quantile(0.5).is_finite());
        assert!(s.quantile(1.0).is_nan()); // NaN sorts above +inf
        // resamples that drew a non-finite value produce non-finite stds,
        // which sort to the ends; the call must complete either way
        let _ = s.bootstrap_std_ci(50, 0.9, 3);
    }
}
