//! Welford online mean/variance with parallel merge — the aggregator's
//! workhorse (numerically stable across million-sample campaigns).

/// Online accumulator: count, mean, M2 (sum of squared deviations), extrema.
#[derive(Debug, Clone, Copy, Default)]
pub struct OnlineStats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl OnlineStats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Fold one sample (Welford's update).
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Chan et al. parallel merge: combine two accumulators exactly.
    pub fn merge(&mut self, other: &OnlineStats) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = *other;
            return;
        }
        let n = self.n + other.n;
        let d = other.mean - self.mean;
        self.mean += d * other.n as f64 / n as f64;
        self.m2 += other.m2 + d * d * (self.n as f64 * other.n as f64) / n as f64;
        self.n = n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Samples folded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Running mean (0 when empty).
    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std_dev(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen (+inf when empty).
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Largest sample seen (-inf when empty).
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn matches_naive_two_pass() {
        let xs: Vec<f64> = (0..1000).map(|i| ((i * 37) % 101) as f64 * 0.01 - 0.5).collect();
        let mut s = OnlineStats::new();
        xs.iter().for_each(|&x| s.push(x));
        let (m, v) = naive(&xs);
        assert!((s.mean() - m).abs() < 1e-12);
        assert!((s.variance() - v).abs() < 1e-12);
        assert_eq!(s.count(), 1000);
    }

    #[test]
    fn merge_equals_sequential() {
        let xs: Vec<f64> = (0..500).map(|i| (i as f64).sin()).collect();
        let mut whole = OnlineStats::new();
        xs.iter().for_each(|&x| whole.push(x));
        let mut a = OnlineStats::new();
        let mut b = OnlineStats::new();
        xs[..123].iter().for_each(|&x| a.push(x));
        xs[123..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - whole.mean()).abs() < 1e-12);
        assert!((a.variance() - whole.variance()).abs() < 1e-12);
        assert_eq!(a.min(), whole.min());
        assert_eq!(a.max(), whole.max());
    }

    #[test]
    fn merge_with_empty_is_identity() {
        let mut s = OnlineStats::new();
        s.push(1.0);
        s.push(3.0);
        let before = s;
        s.merge(&OnlineStats::new());
        assert!((s.mean() - before.mean()).abs() < 1e-15);
        let mut empty = OnlineStats::new();
        empty.merge(&before);
        assert!((empty.mean() - 2.0).abs() < 1e-15);
    }

    #[test]
    fn numerically_stable_for_large_offsets() {
        // catastrophic-cancellation stress: tiny variance on a huge mean
        let mut s = OnlineStats::new();
        for i in 0..10_000 {
            s.push(1e9 + (i % 2) as f64);
        }
        assert!((s.variance() - 0.25).abs() < 1e-6, "var {}", s.variance());
    }
}
