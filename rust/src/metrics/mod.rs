//! Statistics + accuracy metrics: Welford online stats, histograms, BER,
//! and the SNR-based accuracy figure of [10] used in Table 1.

mod error;
mod histogram;
mod quantile;
mod welford;

pub use error::{AccuracyReport, ErrorAccumulator};
pub use histogram::Histogram;
pub use quantile::SampleSet;
pub use welford::OnlineStats;
