//! Statistics + accuracy metrics: Welford online stats, histograms, BER,
//! and the SNR-based accuracy figure of [10] used in Table 1.
//!
//! Everything here streams: campaigns fold millions of MAC outcomes into
//! O(1) accumulators ([`OnlineStats`], [`ErrorAccumulator`]) plus a
//! fixed-bin [`Histogram`] (the Fig. 8/9 distributions), with exact
//! parallel merges so sharded execution changes nothing (DESIGN.md §4).
//! [`SampleSet`] keeps raw samples for quantiles and the bootstrap CI on
//! the reported sigma.

mod error;
mod histogram;
mod quantile;
mod welford;

pub use error::{AccuracyReport, ErrorAccumulator};
pub use histogram::Histogram;
pub use quantile::SampleSet;
pub use welford::OnlineStats;
