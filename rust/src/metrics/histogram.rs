//! Fixed-bin histogram for the Fig. 8/9 Monte-Carlo distributions.

/// Equal-width histogram over [lo, hi); out-of-range samples clamp to the
/// edge bins so nothing is silently dropped. Non-finite samples never
/// enter a bin — they are tracked in a separate [`Self::non_finite`]
/// counter (a NaN has no position on the axis; `idx.max(0.0)` used to
/// map it into bin 0, silently corrupting the Fig. 8/9 mode and
/// sparkline).
#[derive(Debug, Clone)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    n: u64,
    non_finite: u64,
}

impl Histogram {
    /// `n_bins` equal-width bins over `[lo, hi)`.
    pub fn new(lo: f64, hi: f64, n_bins: usize) -> Self {
        assert!(hi > lo && n_bins > 0);
        Self { lo, hi, bins: vec![0; n_bins], n: 0, non_finite: 0 }
    }

    /// Count one sample (out-of-range clamps to the edge bins; non-finite
    /// samples are diverted to the [`Self::non_finite`] counter and never
    /// perturb the bins, [`Self::total`], or [`Self::mode`]).
    pub fn push(&mut self, x: f64) {
        if !x.is_finite() {
            self.non_finite += 1;
            return;
        }
        let nb = self.bins.len();
        let idx = ((x - self.lo) / (self.hi - self.lo) * nb as f64).floor();
        let idx = (idx.max(0.0) as usize).min(nb - 1);
        self.bins[idx] += 1;
        self.n += 1;
    }

    /// Per-bin counts, in bin order.
    pub fn counts(&self) -> &[u64] {
        &self.bins
    }

    /// Total finite samples counted into bins.
    pub fn total(&self) -> u64 {
        self.n
    }

    /// Non-finite (NaN/±inf) samples diverted away from the bins.
    pub fn non_finite(&self) -> u64 {
        self.non_finite
    }

    /// The `[lo, hi)` range the bins span.
    pub fn range(&self) -> (f64, f64) {
        (self.lo, self.hi)
    }

    /// Center of bin `i`.
    pub fn bin_center(&self, i: usize) -> f64 {
        let w = (self.hi - self.lo) / self.bins.len() as f64;
        self.lo + (i as f64 + 0.5) * w
    }

    /// Mode bin's center — the histogram peak (Fig. 8/9's visual anchor).
    pub fn mode(&self) -> f64 {
        let (i, _) = self
            .bins
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            // lint:allow(D4): bins.len() >= 1 is a constructor invariant
            .expect("non-empty bins");
        self.bin_center(i)
    }

    /// Render an ASCII sparkline of the distribution (for reports).
    pub fn sparkline(&self) -> String {
        const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let max = self.bins.iter().copied().max().unwrap_or(0).max(1);
        self.bins
            .iter()
            .map(|&c| GLYPHS[(c * 7 / max) as usize])
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bins_and_clamping() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(0.05);
        h.push(0.95);
        h.push(-5.0); // clamps into bin 0
        h.push(5.0); // clamps into bin 9
        assert_eq!(h.counts()[0], 2);
        assert_eq!(h.counts()[9], 2);
        assert_eq!(h.total(), 4);
    }

    #[test]
    fn mode_finds_peak() {
        let mut h = Histogram::new(0.0, 1.0, 10);
        for _ in 0..5 {
            h.push(0.55);
        }
        h.push(0.15);
        assert!((h.mode() - 0.55).abs() < 0.05);
    }

    #[test]
    fn sparkline_length_matches_bins() {
        let mut h = Histogram::new(0.0, 1.0, 25);
        h.push(0.5);
        assert_eq!(h.sparkline().chars().count(), 25);
    }

    #[test]
    fn non_finite_samples_never_reach_bin_0() {
        // regression: `idx.max(0.0)` used to map NaN into bin 0
        let mut h = Histogram::new(0.0, 1.0, 10);
        h.push(f64::NAN);
        h.push(f64::INFINITY);
        h.push(f64::NEG_INFINITY);
        h.push(0.55);
        assert_eq!(h.counts()[0], 0, "NaN leaked into bin 0");
        assert_eq!(h.total(), 1);
        assert_eq!(h.non_finite(), 3);
        // the mode is computed over finite samples only
        assert!((h.mode() - 0.55).abs() < 0.05);
        assert_eq!(h.range(), (0.0, 1.0));
    }
}
