//! Accuracy metrics: normalized sigma (Table 1's "Accuracy (STD.V)"),
//! SNR per [10], and bit-error rate of the reconstructed product.

use super::welford::OnlineStats;

/// Accumulates error samples of (measured - ideal) voltages, normalized by
/// the variant's full-scale output.
#[derive(Debug, Clone, Default)]
pub struct ErrorAccumulator {
    /// Stats of the normalized error e = (v_mult - v_ideal) / full_scale.
    err: OnlineStats,
    /// Stats of the normalized signal s = v_ideal / full_scale.
    sig: OnlineStats,
    /// Stats of the raw output voltage (for Fig. 8/9 axes).
    raw: OnlineStats,
    /// Count of reconstruction errors (product code mismatches).
    bit_errors: u64,
    /// Count of saturation-exit faults (the paper's systematic faults).
    faults: u64,
    n: u64,
}

impl ErrorAccumulator {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self {
            err: OnlineStats::new(),
            sig: OnlineStats::new(),
            raw: OnlineStats::new(),
            ..Default::default()
        }
    }

    /// Record one MAC outcome.
    ///
    /// * `v_mult` — measured analog output (V)
    /// * `v_ideal` — ideal transfer output (V)
    /// * `full_scale` — variant full-scale (V)
    /// * `code_err` — reconstructed product != exact product
    /// * `fault` — saturation-exit flag from the engine/artifact
    pub fn push(
        &mut self,
        v_mult: f64,
        v_ideal: f64,
        full_scale: f64,
        code_err: bool,
        fault: bool,
    ) {
        self.err.push((v_mult - v_ideal) / full_scale);
        self.sig.push(v_ideal / full_scale);
        self.raw.push(v_mult);
        self.bit_errors += u64::from(code_err);
        self.faults += u64::from(fault);
        self.n += 1;
    }

    /// Combine with another accumulator (exact parallel merge).
    pub fn merge(&mut self, other: &ErrorAccumulator) {
        self.err.merge(&other.err);
        self.sig.merge(&other.sig);
        self.raw.merge(&other.raw);
        self.bit_errors += other.bit_errors;
        self.faults += other.faults;
        self.n += other.n;
    }

    /// Outcomes recorded so far.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Stats of the raw (unnormalized) output voltage.
    pub fn raw_stats(&self) -> &OnlineStats {
        &self.raw
    }

    /// Summarize into the paper's accuracy figures.
    pub fn report(&self) -> AccuracyReport {
        let rms = (self.err.variance() + self.err.mean().powi(2)).sqrt();
        let sig_pow = self.sig.variance() + self.sig.mean().powi(2);
        let err_pow = rms * rms;
        AccuracyReport {
            sigma_norm: self.err.std_dev(),
            rms_norm: rms,
            snr_db: if err_pow > 0.0 { 10.0 * (sig_pow / err_pow).log10() } else { f64::INFINITY },
            ber: self.bit_errors as f64 / self.n.max(1) as f64,
            fault_rate: self.faults as f64 / self.n.max(1) as f64,
            n: self.n,
        }
    }
}

/// Summary accuracy figures for one variant/workload.
#[derive(Debug, Clone, Copy)]
pub struct AccuracyReport {
    /// Std-dev of the normalized error — Table 1's "Accuracy (STD.V)".
    pub sigma_norm: f64,
    /// RMS of the normalized error (includes systematic offset).
    pub rms_norm: f64,
    /// Signal-to-error power ratio in dB — the SNR metric of [10].
    pub snr_db: f64,
    /// Fraction of operations whose reconstructed product was wrong.
    pub ber: f64,
    /// Fraction flagged with a saturation-exit (systematic) fault.
    pub fault_rate: f64,
    /// Outcomes the figures are computed over.
    pub n: u64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_outputs_report_zero_error() {
        let mut acc = ErrorAccumulator::new();
        for i in 0..100 {
            let v = i as f64 / 100.0;
            acc.push(v, v, 1.0, false, false);
        }
        let r = acc.report();
        assert_eq!(r.sigma_norm, 0.0);
        assert_eq!(r.rms_norm, 0.0);
        assert_eq!(r.ber, 0.0);
        assert!(r.snr_db.is_infinite());
    }

    #[test]
    fn sigma_matches_injected_noise() {
        let mut acc = ErrorAccumulator::new();
        // deterministic +/-0.01 alternation: sigma = 0.01, mean = 0
        for i in 0..10_000 {
            let e = if i % 2 == 0 { 0.01 } else { -0.01 };
            acc.push(0.5 + e, 0.5, 1.0, false, false);
        }
        let r = acc.report();
        assert!((r.sigma_norm - 0.01).abs() < 1e-6);
        assert!((r.rms_norm - 0.01).abs() < 1e-6);
    }

    #[test]
    fn systematic_offset_hits_rms_not_sigma() {
        let mut acc = ErrorAccumulator::new();
        for _ in 0..100 {
            acc.push(0.6, 0.5, 1.0, false, false);
        }
        let r = acc.report();
        assert!(r.sigma_norm < 1e-12);
        assert!((r.rms_norm - 0.1).abs() < 1e-9);
    }

    #[test]
    fn ber_and_faults_count() {
        let mut acc = ErrorAccumulator::new();
        acc.push(0.5, 0.5, 1.0, true, false);
        acc.push(0.5, 0.5, 1.0, false, true);
        acc.push(0.5, 0.5, 1.0, false, false);
        acc.push(0.5, 0.5, 1.0, true, true);
        let r = acc.report();
        assert!((r.ber - 0.5).abs() < 1e-12);
        assert!((r.fault_rate - 0.5).abs() < 1e-12);
        assert_eq!(r.n, 4);
    }

    #[test]
    fn merge_equals_sequential() {
        let mut a = ErrorAccumulator::new();
        let mut b = ErrorAccumulator::new();
        let mut whole = ErrorAccumulator::new();
        for i in 0..200 {
            let v = (i as f64).sin() * 0.01 + 0.5;
            if i < 77 {
                a.push(v, 0.5, 1.0, i % 3 == 0, false);
            } else {
                b.push(v, 0.5, 1.0, i % 3 == 0, false);
            }
            whole.push(v, 0.5, 1.0, i % 3 == 0, false);
        }
        a.merge(&b);
        let (ra, rw) = (a.report(), whole.report());
        assert!((ra.sigma_norm - rw.sigma_norm).abs() < 1e-12);
        assert!((ra.ber - rw.ber).abs() < 1e-12);
        assert_eq!(ra.n, rw.n);
    }

    #[test]
    fn snr_db_sanity() {
        let mut acc = ErrorAccumulator::new();
        // signal 0.5 constant, error 0.05 constant -> SNR = 20 dB
        for _ in 0..10 {
            acc.push(0.55, 0.5, 1.0, false, false);
        }
        assert!((acc.report().snr_db - 20.0).abs() < 1e-9);
    }
}
