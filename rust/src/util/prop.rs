//! Deterministic property-test driver (proptest is not available offline).
//!
//! [`check`] runs a property over `n` generated cases from a seeded
//! [`Gen`]; failures report the case index and seed so they replay
//! exactly. No shrinking — cases are small by construction.

use crate::montecarlo::SplitMix64;

/// Random case generator with convenience samplers.
pub struct Gen {
    rng: SplitMix64,
}

impl Gen {
    /// Generator seeded for exact replay.
    pub fn new(seed: u64) -> Self {
        Self { rng: SplitMix64::new(seed) }
    }

    /// Uniform integer in `[0, bound)`.
    pub fn u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        self.rng.next_u64() % bound
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.u64((hi - lo + 1) as u64) as usize
    }

    /// Uniform byte in `[lo, hi]` (inclusive).
    pub fn u8_in(&mut self, lo: u8, hi: u8) -> u8 {
        lo + self.u64(u64::from(hi - lo + 1)) as u8
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_f64() * (hi - lo)
    }

    /// Fair coin flip.
    pub fn bool(&mut self) -> bool {
        self.rng.next_u64() & 1 == 1
    }

    /// Normal deviate with the given sigma.
    pub fn normal(&mut self, sigma: f64) -> f64 {
        self.rng.next_normal() * sigma
    }

    /// Uniformly pick one element of a non-empty slice.
    pub fn pick<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.u64(items.len() as u64) as usize]
    }
}

/// Run `prop` over `n` cases. Panics with the failing case index + seed.
pub fn check<F: FnMut(&mut Gen) -> Result<(), String>>(seed: u64, n: u32, mut prop: F) {
    for case in 0..n {
        let case_seed = seed.wrapping_add(u64::from(case)).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut g = Gen::new(case_seed);
        if let Err(msg) = prop(&mut g) {
            // lint:allow(D4): panicking with the failing seed IS this harness's contract
            panic!("property failed on case {case} (seed {seed}): {msg}");
        }
    }
}

/// Assert helper for property bodies.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivial_property() {
        check(1, 100, |g| {
            let x = g.f64_in(0.0, 1.0);
            prop_assert!((0.0..1.0).contains(&x), "x out of range: {x}");
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn check_reports_failures() {
        check(2, 50, |g| {
            let x = g.u8_in(0, 10);
            prop_assert!(x < 10, "hit the boundary {x}");
            Ok(())
        });
    }

    #[test]
    fn gen_ranges_are_inclusive() {
        let mut g = Gen::new(3);
        let mut seen_lo = false;
        let mut seen_hi = false;
        for _ in 0..1000 {
            match g.u8_in(4, 6) {
                4 => seen_lo = true,
                6 => seen_hi = true,
                5 => {}
                other => panic!("out of range {other}"),
            }
        }
        assert!(seen_lo && seen_hi);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut log_a = Vec::new();
        check(7, 5, |g| {
            log_a.push(g.u64(1000));
            Ok(())
        });
        let mut log_b = Vec::new();
        check(7, 5, |g| {
            log_b.push(g.u64(1000));
            Ok(())
        });
        assert_eq!(log_a, log_b);
    }
}
