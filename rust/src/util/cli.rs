//! Tiny CLI argument helper (clap is not available offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positionals, with
//! typed accessors that produce readable errors.

use std::collections::BTreeMap;

/// Parsed arguments: options + positionals, consumed by typed accessors.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, Vec<String>>,
    pos: Vec<String>,
}

impl Args {
    /// Parse from an iterator (usually `std::env::args().skip(1)`).
    /// `flags` lists option names that take no value.
    pub fn parse<I: IntoIterator<Item = String>>(args: I, flags: &[&str]) -> Result<Self, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if body.is_empty() {
                    // `--` terminator: rest are positionals
                    out.pos.extend(it);
                    break;
                }
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.entry(k.to_string()).or_default().push(v.to_string());
                } else if flags.contains(&body) {
                    out.opts.entry(body.to_string()).or_default().push(String::new());
                } else {
                    let v = it
                        .next()
                        .ok_or_else(|| format!("--{body} expects a value"))?;
                    out.opts.entry(body.to_string()).or_default().push(v);
                }
            } else {
                out.pos.push(a);
            }
        }
        Ok(out)
    }

    /// True when `--name` was given (as a flag or with a value).
    pub fn flag(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    /// Last value given for `--name`, if any.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).and_then(|v| v.last()).map(String::as_str)
    }

    /// Parse `--name`'s value into `T`, falling back to `default` when the
    /// option is absent; parse failures name the offending option.
    pub fn opt_parse<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T, String>
    where
        T::Err: std::fmt::Display,
    {
        match self.opt(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| format!("--{name} '{s}': {e}")),
        }
    }

    /// The `idx`-th positional argument, if present.
    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.pos.get(idx).map(String::as_str)
    }

    /// All positional arguments, in order.
    pub fn positionals(&self) -> &[String] {
        &self.pos
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str], flags: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()), flags).unwrap()
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["mc", "--variant", "aid", "--n-mc=500", "--native", "15"], &["native"]);
        assert_eq!(a.positional(0), Some("mc"));
        assert_eq!(a.opt("variant"), Some("aid"));
        assert_eq!(a.opt("n-mc"), Some("500"));
        assert!(a.flag("native"));
        assert_eq!(a.positional(1), Some("15"));
    }

    #[test]
    fn typed_accessor_and_default() {
        let a = parse(&["--n", "42"], &[]);
        assert_eq!(a.opt_parse("n", 0u32).unwrap(), 42);
        assert_eq!(a.opt_parse("missing", 7u32).unwrap(), 7);
        assert!(a.opt_parse::<u32>("n", 0).is_ok());
        let b = parse(&["--n", "nope"], &[]);
        assert!(b.opt_parse::<u32>("n", 0).is_err());
    }

    #[test]
    fn missing_value_errors() {
        let e = Args::parse(["--variant".to_string()], &[]).unwrap_err();
        assert!(e.contains("expects a value"));
    }

    #[test]
    fn double_dash_terminates() {
        let a = parse(&["--x", "1", "--", "--not-an-opt"], &[]);
        assert_eq!(a.positional(0), Some("--not-an-opt"));
    }
}
