//! In-tree infrastructure substrates.
//!
//! This build environment resolves crates fully offline (the only
//! dependency is the vendored `anyhow` subset in `vendor/anyhow`), so the
//! pieces a crates.io project would pull in are implemented here instead:
//! a JSON parser/writer ([`json`]) for the artifact manifest and model
//! card, a TOML-subset parser ([`toml_lite`]) for experiment configs, a
//! deterministic property-test driver ([`prop`]), and a CLI argument
//! helper ([`cli`]).

pub mod cli;
pub mod json;
pub mod prop;
pub mod toml_lite;
