//! In-tree infrastructure substrates.
//!
//! This build environment resolves crates fully offline (the only
//! dependency is the vendored `anyhow` subset in `vendor/anyhow`), so the
//! pieces a crates.io project would pull in are implemented here instead:
//! a JSON parser/writer ([`json`]) for the artifact manifest and model
//! card, a TOML-subset parser ([`toml_lite`]) for experiment configs, a
//! deterministic property-test driver ([`prop`]), and a CLI argument
//! helper ([`cli`]).

pub mod cli;
pub mod json;
pub mod prop;
pub mod toml_lite;

/// FNV-1a over a string's bytes — deterministic across runs and
/// platforms, so anything derived from it (the dse resume fingerprint,
/// `serve`'s cache-shard placement) is stable. The single statement of
/// the constants; do not re-implement locally.
pub fn fnv1a(key: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    #[test]
    fn fnv1a_reference_vectors() {
        // published FNV-1a 64-bit test vectors
        assert_eq!(super::fnv1a(""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(super::fnv1a("a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(super::fnv1a("foobar"), 0x8594_4171_f739_67e8);
    }
}
