//! TOML-subset parser for experiment configs.
//!
//! Supported: `[table]` headers, `[[array-of-tables]]` headers, dotted-free
//! `key = value` pairs with strings, integers, floats, booleans and flat
//! arrays, plus `#` comments. That covers every config in `configs/` while
//! staying a few hundred lines.

use std::collections::BTreeMap;

use super::json::Value;

/// Parse a TOML-subset document into the same [`Value`] tree JSON uses,
/// so config consumers share one access API.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut root = BTreeMap::new();
    // Path of the table currently being filled.
    let mut current: Vec<String> = Vec::new();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        let err = |m: &str| format!("line {}: {m}", lineno + 1);

        if let Some(inner) = line.strip_prefix("[[").and_then(|s| s.strip_suffix("]]")) {
            let path = parse_key_path(inner).map_err(|m| err(&m))?;
            push_array_table(&mut root, &path).map_err(|m| err(&m))?;
            current = path;
        } else if let Some(inner) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
            let path = parse_key_path(inner).map_err(|m| err(&m))?;
            ensure_table(&mut root, &path).map_err(|m| err(&m))?;
            current = path;
        } else if let Some(eq) = find_top_level_eq(line) {
            let key = line[..eq].trim();
            if key.is_empty() {
                return Err(err("empty key"));
            }
            let val = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
            insert(&mut root, &current, key, val).map_err(|m| err(&m))?;
        } else {
            return Err(err("expected `key = value` or a [table] header"));
        }
    }
    Ok(Value::Obj(root))
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_key_path(s: &str) -> Result<Vec<String>, String> {
    let parts: Vec<String> = s.split('.').map(|p| p.trim().to_string()).collect();
    if parts.iter().any(|p| p.is_empty()) {
        return Err(format!("bad table name '{s}'"));
    }
    Ok(parts)
}

fn find_top_level_eq(line: &str) -> Option<usize> {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '=' if !in_str => return Some(i),
            _ => {}
        }
    }
    None
}

fn parse_value(s: &str) -> Result<Value, String> {
    if s.is_empty() {
        return Err("missing value".into());
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body.strip_suffix('"').ok_or("unterminated string")?;
        let mut out = String::new();
        let mut esc = false;
        for c in body.chars() {
            if esc {
                out.push(match c {
                    'n' => '\n',
                    't' => '\t',
                    '\\' => '\\',
                    '"' => '"',
                    other => return Err(format!("unknown escape \\{other}")),
                });
                esc = false;
            } else if c == '\\' {
                esc = true;
            } else {
                out.push(c);
            }
        }
        if esc {
            return Err("dangling escape at end of string".into());
        }
        return Ok(Value::Str(out));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body.strip_suffix(']').ok_or("unterminated array")?.trim();
        if body.is_empty() {
            return Ok(Value::Arr(vec![]));
        }
        let mut items = Vec::new();
        for item in split_top_level(body) {
            items.push(parse_value(item.trim())?);
        }
        return Ok(Value::Arr(items));
    }
    s.replace('_', "")
        .parse::<f64>()
        .map(Value::Num)
        .map_err(|_| format!("cannot parse value '{s}'"))
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

fn ensure_table<'a>(
    root: &'a mut BTreeMap<String, Value>,
    path: &[String],
) -> Result<&'a mut BTreeMap<String, Value>, String> {
    let mut cur = root;
    for key in path {
        let entry = cur
            .entry(key.clone())
            .or_insert_with(|| Value::Obj(BTreeMap::new()));
        cur = match entry {
            Value::Obj(m) => m,
            Value::Arr(a) => match a.last_mut() {
                Some(Value::Obj(m)) => m,
                _ => return Err(format!("'{key}' is not a table")),
            },
            _ => return Err(format!("'{key}' is not a table")),
        };
    }
    Ok(cur)
}

fn push_array_table(root: &mut BTreeMap<String, Value>, path: &[String]) -> Result<(), String> {
    let (last, parents) = path.split_last().ok_or("empty table path")?;
    let parent = ensure_table(root, parents)?;
    match parent
        .entry(last.clone())
        .or_insert_with(|| Value::Arr(Vec::new()))
    {
        Value::Arr(a) => {
            a.push(Value::Obj(BTreeMap::new()));
            Ok(())
        }
        _ => Err(format!("'{last}' is not an array of tables")),
    }
}

fn insert(
    root: &mut BTreeMap<String, Value>,
    table: &[String],
    key: &str,
    val: Value,
) -> Result<(), String> {
    let t = ensure_table(root, table)?;
    if t.insert(key.to_string(), val).is_some() {
        return Err(format!("duplicate key '{key}'"));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
        # experiment
        name = "fig8"
        n = 42
        ratio = 0.5
        flag = true
        list = [1, 2, 3]

        [params.circuit]
        c_blb = 3e-14

        [[campaigns]]
        variant = "smart"
        n_mc = 1000

        [[campaigns]]
        variant = "aid"   # inline comment
        n_mc = 1_000
    "#;

    #[test]
    fn parses_document() {
        let v = parse(DOC).unwrap();
        assert_eq!(v.get("name").unwrap().as_str(), Some("fig8"));
        assert_eq!(v.get("n").unwrap().as_u64(), Some(42));
        assert_eq!(v.get("flag").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("list").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.path(&["params", "circuit", "c_blb"]).unwrap().as_f64(), Some(3e-14));
        let camps = v.get("campaigns").unwrap().as_arr().unwrap();
        assert_eq!(camps.len(), 2);
        assert_eq!(camps[0].get("variant").unwrap().as_str(), Some("smart"));
        assert_eq!(camps[1].get("n_mc").unwrap().as_u64(), Some(1000));
    }

    #[test]
    fn keys_after_array_table_attach_to_last_element() {
        let v = parse("[[c]]\nx = 1\n[[c]]\nx = 2\n").unwrap();
        let c = v.get("c").unwrap().as_arr().unwrap();
        assert_eq!(c[0].get("x").unwrap().as_u64(), Some(1));
        assert_eq!(c[1].get("x").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn nested_table_under_array_element() {
        let v = parse("[[c]]\n[c.w]\nkind = \"fixed\"\n").unwrap();
        let c = v.get("c").unwrap().as_arr().unwrap();
        assert_eq!(c[0].path(&["w", "kind"]).unwrap().as_str(), Some("fixed"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse("x = ").unwrap_err();
        assert!(e.starts_with("line 1"), "{e}");
        let e = parse("ok = 1\nbroken").unwrap_err();
        assert!(e.starts_with("line 2"), "{e}");
    }

    #[test]
    fn rejects_duplicates() {
        assert!(parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn strings_with_hashes_and_escapes() {
        let v = parse(r#"s = "a # not comment \n b""#).unwrap();
        assert_eq!(v.get("s").unwrap().as_str(), Some("a # not comment \n b"));
    }

    #[test]
    fn rejects_dangling_escape() {
        // a lone trailing backslash used to be dropped silently
        assert!(parse("s = \"oops\\\"").is_err());
    }

    #[test]
    fn scalar_where_table_expected_errors() {
        assert!(parse("a = 1\n[a.b]\nx = 2\n").is_err());
        assert!(parse("a = 1\n[[a]]\n").is_err());
    }
}
