//! Minimal JSON: a recursive-descent parser and a pretty writer.
//!
//! Covers the full JSON grammar (RFC 8259) minus surrogate-pair escapes;
//! used for `artifacts/manifest.json` and `artifacts/params.json`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a BTreeMap so serialization is
/// deterministic (matching Python's `sort_keys=True`).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    /// JSON `null`.
    Null,
    /// JSON boolean.
    Bool(bool),
    /// JSON number (all numbers are f64, as in JavaScript).
    Num(f64),
    /// JSON string.
    Str(String),
    /// JSON array.
    Arr(Vec<Value>),
    /// JSON object (sorted keys — deterministic serialization).
    Obj(BTreeMap<String, Value>),
}

impl Value {
    /// Object field access (`None` for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// The number, if this is one.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// The number as an exact non-negative integer, if it is one.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as u64)
    }

    /// The string, if this is one.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    /// The array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// The boolean, if this is one.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Fetch a nested field: `v.path(&["device", "vth0"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Value> {
        keys.iter().try_fold(self, |v, k| v.get(k))
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq)]
pub struct ParseError {
    /// What went wrong.
    pub msg: String,
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

/// Parse a complete JSON document.
pub fn parse(text: &str) -> Result<Value, ParseError> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(p.err("trailing characters"));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Value) -> Result<Value, ParseError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Value::Null),
            Some(b't') => self.lit("true", Value::Bool(true)),
            Some(b'f') => self.lit("false", Value::Bool(false)),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.i += 1;
                    match c {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(ch) = rest.chars().next() else {
                        return Err(self.err("unterminated string"));
                    };
                    s.push(ch);
                    self.i += ch.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])
            .map_err(|_| self.err("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| ParseError { msg: format!("bad number '{text}'"), offset: start })
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

/// Serialize with 2-space indentation (diff-friendly artifacts).
pub fn to_string_pretty(v: &Value) -> String {
    let mut s = String::new();
    write_value(v, 0, &mut s);
    s
}

/// Serialize as one compact line, no whitespace (JSONL trace records).
/// Number/string/escape rendering is identical to [`to_string_pretty`],
/// so the two forms parse back to the same [`Value`].
pub fn to_string_compact(v: &Value) -> String {
    let mut s = String::new();
    write_compact(v, &mut s);
    s
}

fn write_num(n: f64, out: &mut String) {
    // -0.0 == 0.0 numerically but renders with a sign; normalize so
    // artifacts and cache keys never diverge on sign-of-zero (the
    // same rule as report::canon_zero)
    let n = if n == 0.0 { 0.0 } else { n };
    if !n.is_finite() {
        // JSON has no NaN/Infinity literal; emitting one would
        // produce a document parse() itself rejects
        out.push_str("null");
    } else if n.fract() == 0.0 && n.abs() < 1e15 {
        // fract() == 0 and |n| < 1e15 make the i64 conversion exact
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_compact(v: &Value, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_compact(item, out);
            }
            out.push(']');
        }
        Value::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_string(k, out);
                out.push(':');
                write_compact(val, out);
            }
            out.push('}');
        }
    }
}

fn write_value(v: &Value, indent: usize, out: &mut String) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Value::Num(n) => write_num(*n, out),
        Value::Str(s) => write_string(s, out),
        Value::Arr(a) => {
            if a.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_value(item, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push(']');
        }
        Value::Obj(m) => {
            if m.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                out.push('\n');
                out.push_str(&"  ".repeat(indent + 1));
                write_string(k, out);
                out.push_str(": ");
                write_value(val, indent + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(indent));
            out.push('}');
        }
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Value::Null);
        assert_eq!(parse("true").unwrap(), Value::Bool(true));
        assert_eq!(parse("-1.5e-3").unwrap(), Value::Num(-1.5e-3));
        assert_eq!(parse("\"a\\nb\"").unwrap(), Value::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.path(&["a"]).unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn parses_python_params_json() {
        // exactly the shape aot.py emits
        let text = r#"{
          "circuit": {"c_blb": 3e-14, "n_bits": 4, "n_steps": 256},
          "device": {"vth0": 0.3, "gamma": 0.306}
        }"#;
        let v = parse(text).unwrap();
        assert_eq!(v.path(&["device", "vth0"]).unwrap().as_f64(), Some(0.3));
        assert_eq!(v.path(&["circuit", "n_steps"]).unwrap().as_u64(), Some(256));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(parse(r#""Aé""#).unwrap(), Value::Str("Aé".into()));
    }

    #[test]
    fn roundtrip_pretty() {
        let v = parse(r#"{"b": [1, 2.5, true], "a": {"x": "y"}, "e": []}"#).unwrap();
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
        // deterministic key order
        let i_a = text.find("\"a\"").unwrap();
        let i_b = text.find("\"b\"").unwrap();
        assert!(i_a < i_b);
    }

    #[test]
    fn writer_never_emits_unparseable_numbers() {
        for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
            let text = to_string_pretty(&Value::Num(bad));
            assert_eq!(parse(&text).unwrap(), Value::Null);
        }
    }

    #[test]
    fn writer_normalizes_negative_zero() {
        // regression: -0.0 must serialize exactly as 0.0 so byte-identical
        // pipelines can never diverge textually on sign-of-zero
        assert_eq!(to_string_pretty(&Value::Num(-0.0)), "0");
        assert_eq!(to_string_pretty(&Value::Num(0.0)), "0");
        let arr = Value::Arr(vec![Value::Num(-0.0), Value::Num(-1.5)]);
        let text = to_string_pretty(&arr);
        assert!(!text.contains("-0,") && !text.contains("-0\n"), "sign leaked: {text}");
        assert!(text.contains("-1.5"));
    }

    #[test]
    fn escapes_in_writer() {
        let v = Value::Str("a\"b\\c\nd".into());
        let text = to_string_pretty(&v);
        assert_eq!(parse(&text).unwrap(), v);
    }

    #[test]
    fn compact_is_one_line_and_roundtrips_like_pretty() {
        let v = parse(r#"{"b": [1, 2.5, true], "a": {"x": "y\n"}, "e": [], "n": null}"#).unwrap();
        let compact = to_string_compact(&v);
        assert!(!compact.contains('\n') && !compact.contains(' '), "{compact}");
        assert_eq!(parse(&compact).unwrap(), v);
        assert_eq!(parse(&compact).unwrap(), parse(&to_string_pretty(&v)).unwrap());
        // same number normalization as the pretty writer
        assert_eq!(to_string_compact(&Value::Num(-0.0)), "0");
        assert_eq!(to_string_compact(&Value::Num(f64::NAN)), "null");
        assert_eq!(to_string_compact(&Value::Arr(vec![Value::Num(2.0)])), "[2]");
    }
}
