//! Word-line DACs: Eq. 7 (IMAC [9], linear) and Eq. 8 (AID [10], sqrt),
//! with optional INL and thermal-noise injection for BER studies.

use crate::params::{CircuitCard, DeviceCard};

/// DAC transfer curve selecting how the digital operand B maps onto V_WL.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DacMode {
    /// Eq. 7 — IMAC [9]: V_WL = VTH + code/(2^N-1) * (WL_MAX - VTH).
    /// Current (and thus discharge) is *quadratic* in the code.
    Linear,
    /// Eq. 8 — AID [10]: V_WL = VTH + sqrt(code/(2^N-1)) * (WL_MAX - VTH).
    /// Linearizes I ~ (V_WL - VTH)^2 in the code.
    Sqrt,
}

impl DacMode {
    /// Numeric flag matching the L2 model's traced `dac_mode` input.
    pub fn flag(self) -> f32 {
        match self {
            Self::Linear => 0.0,
            Self::Sqrt => 1.0,
        }
    }
}

/// A word-line DAC calibrated to a *design* threshold (the nominal
/// effective VTH — the designer knows the body bias, not the mismatch).
#[derive(Debug, Clone, Copy)]
pub struct WordlineDac {
    /// Transfer curve (Eq. 7 linear / Eq. 8 sqrt).
    pub mode: DacMode,
    /// Design threshold the code range is anchored to (V).
    pub vth_design: f64,
    /// Top of the WL range (V).
    pub wl_max: f64,
    /// Levels: 2^N - 1.
    pub full_code: f64,
    /// Peak INL as a fraction of one code step (0 = ideal).
    pub inl: f64,
    /// RMS output noise (V); sampled externally, exposed as a sigma.
    pub sigma_noise: f64,
}

impl WordlineDac {
    /// DAC for a variant: anchored to the body-biased nominal threshold.
    pub fn new(mode: DacMode, device: &DeviceCard, circuit: &CircuitCard, v_bulk: f64) -> Self {
        Self {
            mode,
            vth_design: device.vth_effective(v_bulk, 0.0),
            wl_max: circuit.wl_max,
            full_code: circuit.full_code(),
            inl: 0.0,
            sigma_noise: 0.0,
        }
    }

    /// Ideal output voltage for `code` (0 grounds the WL — no pulse).
    pub fn v_wl(&self, code: u8) -> f64 {
        assert!((code as f64) <= self.full_code, "code {code} out of range");
        if code == 0 {
            return 0.0;
        }
        let frac = code as f64 / self.full_code;
        let margin = self.wl_max - self.vth_design;
        let shaped = match self.mode {
            DacMode::Linear => frac,
            DacMode::Sqrt => frac.sqrt(),
        };
        let ideal = self.vth_design + shaped * margin;
        // Parabolic INL profile: zero at the range ends, peak mid-scale.
        let step = margin / self.full_code;
        ideal + self.inl * step * 4.0 * frac * (1.0 - frac)
    }

    /// Per-code voltage step margin of the *shaped* range (V) — the
    /// quantity the paper's accuracy argument is about (§I: the margin
    /// improves by VTH/(VDD-VTH) when VTH is suppressed).
    pub fn code_step(&self) -> f64 {
        (self.wl_max - self.vth_design) / self.full_code
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::{CircuitCard, DeviceCard};

    fn dac(mode: DacMode, v_bulk: f64) -> WordlineDac {
        WordlineDac::new(mode, &DeviceCard::default(), &CircuitCard::default(), v_bulk)
    }

    #[test]
    fn linear_levels_equispaced() {
        let d = dac(DacMode::Linear, 0.0);
        let levels: Vec<f64> = (1..=15).map(|c| d.v_wl(c)).collect();
        let step = levels[1] - levels[0];
        for w in levels.windows(2) {
            assert!((w[1] - w[0] - step).abs() < 1e-12);
        }
        assert!((levels[14] - 0.70).abs() < 1e-12);
        assert!((levels[0] - (0.30 + step)).abs() < 1e-9);
    }

    #[test]
    fn sqrt_levels_linearize_squared_overdrive() {
        let d = dac(DacMode::Sqrt, 0.0);
        for c in 1..=15u8 {
            let vov = d.v_wl(c) - d.vth_design;
            let want = (c as f64 / 15.0) * (d.wl_max - d.vth_design).powi(2);
            assert!((vov * vov - want).abs() < 1e-12);
        }
    }

    #[test]
    fn zero_code_grounds_wordline() {
        assert_eq!(dac(DacMode::Linear, 0.0).v_wl(0), 0.0);
        assert_eq!(dac(DacMode::Sqrt, 0.6).v_wl(0), 0.0);
    }

    #[test]
    fn body_bias_widens_code_step() {
        // Paper §III: [300,700] -> [175,700] mV gives 26.7 -> 35 mV steps.
        let base = dac(DacMode::Linear, 0.0).code_step();
        let smart = dac(DacMode::Linear, 0.6).code_step();
        assert!((base - 0.0267).abs() < 5e-4, "base step {base}");
        assert!((smart - 0.0350).abs() < 5e-4, "smart step {smart}");
    }

    #[test]
    fn inl_vanishes_at_range_ends() {
        let mut d = dac(DacMode::Linear, 0.0);
        let ideal_top = d.v_wl(15);
        d.inl = 0.5;
        assert!((d.v_wl(15) - ideal_top).abs() < 1e-12);
        // mid-scale deviates
        let mut ideal_mid = dac(DacMode::Linear, 0.0);
        ideal_mid.inl = 0.0;
        assert!((d.v_wl(8) - ideal_mid.v_wl(8)).abs() > 1e-4);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn code_out_of_range_panics() {
        dac(DacMode::Linear, 0.0).v_wl(16);
    }
}
