//! TOML-lite experiment configuration: a campaign file a user can check in.

use std::path::Path;

use anyhow::{Context, Result};

use crate::coordinator::CampaignSpec;
use crate::params::Params;
use crate::util::{json::Value, toml_lite};

/// A checked-in experiment: model-card overrides plus one or more campaigns.
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentConfig {
    /// Optional human label for reports.
    pub name: String,
    /// Model card (defaults + any `[params.*]` overrides).
    pub params: Params,
    /// Campaigns to run, in order.
    pub campaigns: Vec<CampaignSpec>,
}

impl ExperimentConfig {
    /// Load and parse an experiment file from disk.
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse an experiment document (TOML-lite).
    pub fn parse(text: &str) -> Result<Self> {
        let doc = toml_lite::parse(text).map_err(|e| anyhow::anyhow!("experiment TOML: {e}"))?;
        let name = doc
            .get("name")
            .and_then(Value::as_str)
            .unwrap_or("")
            .to_string();
        let mut params = Params::default();
        if let Some(p) = doc.get("params") {
            params.apply_overrides(p).context("[params] overrides")?;
        }
        let mut campaigns = Vec::new();
        let arr = doc
            .get("campaigns")
            .and_then(Value::as_arr)
            .ok_or_else(|| anyhow::anyhow!("no [[campaigns]] in config"))?;
        for (i, c) in arr.iter().enumerate() {
            campaigns.push(
                CampaignSpec::from_value(c).with_context(|| format!("campaign #{i}"))?,
            );
        }
        Ok(Self { name, params, campaigns })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Workload;
    use crate::mac::Variant;

    const EXAMPLE: &str = r#"
        name = "fig8"
        [[campaigns]]
        variant = "smart"
        n_mc = 1000
        seed = 2022
        [campaigns.workload]
        kind = "fixed"
        a = 15
        b = 15
    "#;

    #[test]
    fn parses_minimal_campaign() {
        let cfg = ExperimentConfig::parse(EXAMPLE).unwrap();
        assert_eq!(cfg.name, "fig8");
        assert_eq!(cfg.campaigns.len(), 1);
        let c = &cfg.campaigns[0];
        assert_eq!(c.variant, Variant::Smart);
        assert_eq!(c.workload, Workload::Fixed { a: 15, b: 15 });
        assert_eq!(c.n_mc, 1000);
        assert_eq!(c.workers, 0);
        assert_eq!(c.batch, 0);
        assert_eq!(cfg.params, Params::default());
    }

    #[test]
    fn rejects_invalid_campaign() {
        let bad = EXAMPLE.replace("a = 15", "a = 99");
        assert!(ExperimentConfig::parse(&bad).is_err());
    }

    #[test]
    fn rejects_empty_config() {
        assert!(ExperimentConfig::parse("name = \"x\"\n").is_err());
    }

    #[test]
    fn params_override() {
        let text = format!("{EXAMPLE}\n[params.circuit]\nc_blb = 45e-15\n");
        let cfg = ExperimentConfig::parse(&text).unwrap();
        assert_eq!(cfg.params.circuit.c_blb, 45e-15);
        assert_eq!(cfg.params.circuit.wl_max, 0.70); // untouched default
    }

    #[test]
    fn multi_campaign_order_preserved() {
        let text = r#"
            [[campaigns]]
            variant = "aid"
            [campaigns.workload]
            kind = "full_sweep"
            [[campaigns]]
            variant = "imac"
            [campaigns.workload]
            kind = "random"
            n_ops = 10
        "#;
        let cfg = ExperimentConfig::parse(text).unwrap();
        assert_eq!(cfg.campaigns[0].variant, Variant::Aid);
        assert_eq!(cfg.campaigns[1].variant, Variant::Imac);
        assert_eq!(cfg.campaigns[1].workload, Workload::Random { n_ops: 10 });
    }
}
