//! End-to-end noisy-inference demo on the **native** block-execution
//! path (`rust/src/nn/`, DESIGN.md §10): the checked-in fixture MLP
//! with every multiply-accumulate executed by the simulated analog MAC,
//! head-to-head across the paper's design variants.
//!
//! ```bash
//! cargo run --offline --release --example nn_infer
//! ```
//!
//! Prints the ideal (exact integer) top-1 accuracy, then each variant's
//! noisy accuracy, agreement with the exact pipeline, output error, and
//! energy per inference — SMART's suppressed threshold shrinks the
//! application-level noise penalty at the same supply. (The sibling
//! `nn_inference` example drives the AOT/PJRT path instead.)

use anyhow::Result;
use smart_insram::mac::Variant;
use smart_insram::nn::{run_infer, InferOptions, ModelSpec};
use smart_insram::params::Params;

fn main() -> Result<()> {
    let params = Params::default();
    let spec = match ModelSpec::load("configs/nn.toml") {
        Ok(s) => s,
        Err(_) => ModelSpec::fixture(), // run from any cwd
    };
    let trials = 32u32;

    // Noise off: the analog pipeline collapses to the exact integer one.
    let quiet = InferOptions { trials, noise_off: true, ..InferOptions::default() };
    let ideal = run_infer(&params, &spec, &quiet)?;
    assert_eq!(ideal.noisy_accuracy, ideal.ideal_accuracy);
    println!(
        "model '{}': {} MACs/inference, exact top-1 {:.1}% ({} trials)\n",
        ideal.name,
        ideal.macs_per_inference,
        ideal.ideal_accuracy * 100.0,
        trials
    );

    println!(
        "{:<14} {:>9} {:>10} {:>10} {:>13} {:>12}",
        "variant", "noisy", "vs-exact", "out-err", "pJ/inference", "MAC evals/s"
    );
    for variant in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let opts = InferOptions { trials, variant, ..InferOptions::default() };
        let r = run_infer(&params, &spec, &opts)?;
        println!(
            "{:<14} {:>8.1}% {:>9.1}% {:>10.4} {:>13.2} {:>12.0}",
            variant.name(),
            r.noisy_accuracy * 100.0,
            r.agreement * 100.0,
            r.out_err.mean(),
            r.energy_per_inference_pj,
            r.throughput()
        );
    }
    println!("\n(noisy = top-1 on the synthetic labels; vs-exact = agreement with integer math)");
    Ok(())
}
