//! Design-space exploration: the device/circuit-level figures (Fig. 3,
//! Fig. 4, Fig. 5/6) plus two design ablations the paper calls out —
//! WL-margin widening and sampling-time sensitivity.
//!
//! ```bash
//! cargo run --offline --release --example design_space [out_dir]
//! ```
//!
//! Emits CSV series (one file per figure) and prints the headline
//! observables: the ~125 mV turn-on shift, the per-width current gain,
//! and the discharge speed-up.

use anyhow::Result;
use smart_insram::circuit::{discharge_trace, BitlineInputs};
use smart_insram::dac::{DacMode, WordlineDac};
use smart_insram::device::{iv_sweep, width_sweep, Mosfet};
use smart_insram::mac::{NativeMacEngine, Variant};
use smart_insram::montecarlo::McSample;
use smart_insram::params::Params;
use smart_insram::report::csv;

fn main() -> Result<()> {
    let params = Params::default();
    let card = params.device;
    let out_dir = std::env::args().nth(1).unwrap_or_else(|| "target/figures".into());
    std::fs::create_dir_all(&out_dir)?;
    let write = |name: &str, text: String| -> Result<()> {
        let path = format!("{out_dir}/{name}");
        std::fs::write(&path, text)?;
        println!("wrote {path}");
        Ok(())
    };

    // ---- Fig. 3: I_D(V_WL) for V_bulk in {0, 0.2, 0.4, 0.6} V -------------
    let bulks = [0.0, 0.2, 0.4, 0.6];
    let pts = iv_sweep(card, &bulks, 201);
    let rows: Vec<Vec<f64>> = pts.iter().map(|p| vec![p.v_wl, p.v_bulk, p.i_d]).collect();
    write("fig3_iv.csv", csv(&["v_wl", "v_bulk", "i_d"], &rows))?;
    let dev = Mosfet::nominal(card);
    let v_at = |vb: f64| {
        (0..=2000)
            .map(|k| k as f64 * 0.0005)
            .find(|&v| dev.drain_current(v, card.vdd, vb) > 10e-6)
            .unwrap()
    };
    let shift = v_at(0.0) - v_at(0.6);
    println!("Fig.3: turn-on shift at 0.6 V body bias = {:.1} mV (paper: ~125 mV)", shift * 1e3);

    // ---- Fig. 4: width sweep, V_bulk = 0 solid vs 0.6 dashed --------------
    let ws: Vec<f64> = (1..=20).map(|k| k as f64 * 0.25).collect();
    let pts = width_sweep(card, 0.55, &[0.0, 0.6], &ws);
    let rows: Vec<Vec<f64>> = pts.iter().map(|p| vec![p.w_scale, p.v_bulk, p.i_d]).collect();
    write("fig4_width.csv", csv(&["w_scale", "v_bulk", "i_d"], &rows))?;
    let gain = pts[ws.len()].i_d / pts[0].i_d;
    println!("Fig.4: body-bias current gain at W-scale 0.25 = {gain:.2}x (uniform across widths)");

    // ---- Fig. 5/6: V_BLB(t) discharge, biased vs unbiased ------------------
    for (fig, variant) in [("fig6", Variant::Aid), ("fig5", Variant::Imac)] {
        let cfg = variant.config(&params);
        let dac = WordlineDac::new(cfg.dac_mode, &card, &params.circuit, 0.0);
        let v_wl = dac.v_wl(15);
        let mut rows = Vec::new();
        for vb in [0.0, 0.6] {
            let inp = BitlineInputs { v_wl, bit: true, v_bulk: vb };
            let wf = discharge_trace(&params, &Mosfet::nominal(card), &inp, 1.0e-9, 512, 8);
            for (t, v) in wf.iter() {
                rows.push(vec![t, vb, v]);
            }
        }
        write(
            &format!("{fig}_discharge_{}.csv", variant.name().split_whitespace().next().unwrap()),
            csv(&["t", "v_bulk", "v_blb"], &rows),
        )?;
    }
    // discharge speed-up headline
    let inp0 = BitlineInputs { v_wl: 0.55, bit: true, v_bulk: 0.0 };
    let inp6 = BitlineInputs { v_wl: 0.55, bit: true, v_bulk: 0.6 };
    let wf0 = discharge_trace(&params, &Mosfet::nominal(card), &inp0, 2.0e-9, 1024, 8);
    let wf6 = discharge_trace(&params, &Mosfet::nominal(card), &inp6, 2.0e-9, 1024, 8);
    let t0 = wf0.crossing_time(0.7).unwrap_or(f64::NAN);
    let t6 = wf6.crossing_time(0.7).unwrap_or(f64::NAN);
    println!(
        "Fig.5/6: time to 0.3 V discharge — unbiased {:.0} ps vs biased {:.0} ps ({:.2}x faster)",
        t0 * 1e12,
        t6 * 1e12,
        t0 / t6
    );

    // ---- Ablation A: WL margin / DAC levels (paper §III) ------------------
    let mut rows = Vec::new();
    for (label, vb) in [(0.0f64, 0.0f64), (1.0, 0.6)] {
        for mode in [DacMode::Linear, DacMode::Sqrt] {
            let dac = WordlineDac::new(mode, &card, &params.circuit, vb);
            for c in 0..=15u8 {
                rows.push(vec![
                    label,
                    if mode == DacMode::Linear { 0.0 } else { 1.0 },
                    f64::from(c),
                    dac.v_wl(c),
                ]);
            }
        }
    }
    write("ablation_wl_margin.csv", csv(&["biased", "sqrt_mode", "code", "v_wl"], &rows))?;
    let base = WordlineDac::new(DacMode::Sqrt, &card, &params.circuit, 0.0);
    let smart = WordlineDac::new(DacMode::Sqrt, &card, &params.circuit, 0.6);
    println!(
        "Ablation A: WL margin [{:.0}, 700] -> [{:.0}, 700] mV; code step {:.1} -> {:.1} mV",
        base.vth_design * 1e3,
        smart.vth_design * 1e3,
        base.code_step() * 1e3,
        smart.code_step() * 1e3
    );

    // ---- Ablation B: accuracy vs sampling time (Eq. 4 validity) -----------
    let mut rows = Vec::new();
    println!("Ablation B: fault onset vs WL pulse width (Eq. 4):");
    for variant in [Variant::Smart, Variant::Aid] {
        let mut first_fault = None;
        for k in 1..=40 {
            let t_s = k as f64 * 2.5e-11; // 25 ps steps up to 1 ns
            let mut cfg = variant.config(&params);
            cfg.t_sample = t_s;
            let engine = NativeMacEngine::new(params, cfg);
            let r = engine.mac(15, 15, &McSample::nominal());
            rows.push(vec![
                if variant == Variant::Smart { 1.0 } else { 0.0 },
                t_s,
                r.v_mult,
                f64::from(u8::from(r.fault)),
            ]);
            if r.fault && first_fault.is_none() {
                first_fault = Some(t_s);
            }
        }
        println!(
            "  {:<14} first saturation-exit fault at t_s = {:.0} ps",
            variant.name(),
            first_fault.unwrap_or(f64::NAN) * 1e12
        );
    }
    write("ablation_t_sample.csv", csv(&["smart", "t_s", "v_mult", "fault"], &rows))?;

    Ok(())
}
