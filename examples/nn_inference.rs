//! Motivating workload (paper §I): neural-network inference on the
//! analog in-SRAM MAC — a 2-layer 4-bit MLP classifying synthetic
//! 16-pixel digit patterns, with every multiply executed by the analog
//! accelerator through the AOT/PJRT path.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example nn_inference
//! ```
//!
//! Reports classification agreement vs exact integer math per variant
//! (SMART's lower sigma -> higher agreement), plus throughput and the
//! energy-per-inference estimate from the Table 1 model.

use anyhow::Result;
use smart_insram::energy::{nominal_cost, EnergyModel};
use smart_insram::mac::{IdealTransfer, NativeMacEngine, Variant};
use smart_insram::montecarlo::{MismatchSampler, SplitMix64};
use smart_insram::params::Params;
use smart_insram::runtime::{default_artifact_dir, MacBatch, XlaRuntime};

const N_IN: usize = 16; // 4x4 binary pixel pattern
const N_HID: usize = 8;
const N_OUT: usize = 4; // four synthetic classes
const N_SAMPLES: usize = 128;
const BATCH: usize = 256;

/// Tiny fixed-point MLP with 4-bit unsigned weights/activations.
struct Mlp {
    w1: [[u8; N_IN]; N_HID],
    w2: [[u8; N_HID]; N_OUT],
}

impl Mlp {
    /// Deterministic "trained" weights: each hidden unit prefers one
    /// quadrant + stripe pattern, each output sums matching hidden units.
    fn new(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let mut w1 = [[0u8; N_IN]; N_HID];
        for (h, row) in w1.iter_mut().enumerate() {
            for (i, w) in row.iter_mut().enumerate() {
                let quadrant = (i % 4 >= 2) as usize + 2 * (i / 8);
                let on = quadrant == h % 4 || (i + h) % 5 == 0;
                *w = if on { 8 + (rng.next_u64() % 8) as u8 } else { (rng.next_u64() % 3) as u8 };
            }
        }
        let mut w2 = [[0u8; N_HID]; N_OUT];
        for (o, row) in w2.iter_mut().enumerate() {
            for (h, w) in row.iter_mut().enumerate() {
                *w = if h % N_OUT == o {
                    10 + (rng.next_u64() % 6) as u8
                } else {
                    (rng.next_u64() % 4) as u8
                };
            }
        }
        Self { w1, w2 }
    }
}

/// 4-bit requantization of an integer accumulator.
fn quant4(acc: u32, scale: u32) -> u8 {
    ((acc / scale).min(15)) as u8
}

fn exact_forward(mlp: &Mlp, x: &[u8; N_IN]) -> usize {
    let mut hid = [0u8; N_HID];
    for h in 0..N_HID {
        let acc: u32 = (0..N_IN).map(|i| u32::from(mlp.w1[h][i]) * u32::from(x[i])).sum();
        hid[h] = quant4(acc, 60);
    }
    let mut best = (0usize, 0u32);
    for o in 0..N_OUT {
        let acc: u32 = (0..N_HID).map(|h| u32::from(mlp.w2[o][h]) * u32::from(hid[h])).sum();
        if acc > best.1 {
            best = (o, acc);
        }
    }
    best.0
}

/// Analog forward pass: every multiply runs as one in-SRAM MAC through the
/// AOT executable; accumulation happens digitally in the coordinator
/// (bit-serial column architecture, paper Fig. 7).
struct AnalogRunner<'a> {
    exe: &'a smart_insram::runtime::MacExecutable,
    ideal: IdealTransfer,
    cfg: smart_insram::mac::VariantConfig,
    sampler: MismatchSampler,
    macs: u64,
}

impl<'a> AnalogRunner<'a> {
    /// Execute a list of (a, b) products; returns reconstructed products.
    fn products(&mut self, pairs: &[(u8, u8)]) -> Result<Vec<u16>> {
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(BATCH) {
            let mut batch = MacBatch::nominal(
                BATCH,
                self.cfg.v_bulk as f32,
                self.cfg.dac_mode.flag(),
                self.cfg.t_sample as f32,
            );
            for (i, &(a, b)) in chunk.iter().enumerate() {
                let mc = self.sampler.sample();
                batch.set_row(i, a, b, mc.dvth.map(|x| x as f32), mc.dbeta.map(|x| x as f32));
            }
            let res = self.exe.run(&batch)?;
            for i in 0..chunk.len() {
                out.push(smart_insram::mac::reconstruct(
                    &self.ideal,
                    f64::from(res.v_mult[i]),
                ));
            }
            self.macs += chunk.len() as u64;
        }
        Ok(out)
    }

    fn forward(&mut self, mlp: &Mlp, x: &[u8; N_IN]) -> Result<usize> {
        // layer 1: N_HID x N_IN products
        let pairs: Vec<(u8, u8)> = (0..N_HID)
            .flat_map(|h| (0..N_IN).map(move |i| (h, i)))
            .map(|(h, i)| (mlp.w1[h][i], x[i]))
            .collect();
        let prods = self.products(&pairs)?;
        let mut hid = [0u8; N_HID];
        for h in 0..N_HID {
            let acc: u32 = (0..N_IN).map(|i| u32::from(prods[h * N_IN + i])).sum();
            hid[h] = quant4(acc, 60);
        }
        // layer 2
        let pairs: Vec<(u8, u8)> = (0..N_OUT)
            .flat_map(|o| (0..N_HID).map(move |h| (o, h)))
            .map(|(o, h)| (mlp.w2[o][h], hid[h]))
            .collect();
        let prods = self.products(&pairs)?;
        let mut best = (0usize, 0u32);
        for o in 0..N_OUT {
            let acc: u32 = (0..N_HID).map(|h| u32::from(prods[o * N_HID + h])).sum();
            if acc > best.1 {
                best = (o, acc);
            }
        }
        Ok(best.0)
    }
}

fn synth_input(rng: &mut SplitMix64, class: usize) -> [u8; N_IN] {
    let mut x = [0u8; N_IN];
    for (i, px) in x.iter_mut().enumerate() {
        let quadrant = (i % 4 >= 2) as usize + 2 * (i / 8);
        let base = if quadrant == class { 11 } else { 2 };
        let noise = (rng.next_u64() % 5) as i32 - 2;
        *px = (base + noise).clamp(0, 15) as u8;
    }
    x
}

/// VMM execution: whole dot products on the multi-row array artifact
/// (Fig. 7 used as IMAC-class accelerators intend). Layer 1 is 8 dots of
/// 16 rows per sample; layer 2 is 4 dots of 8 rows (zero-padded to 16).
struct VmmRunner<'a> {
    exe: &'a smart_insram::runtime::DotExecutable,
    ideal_fs: f64, // full-scale v_dot == R x 225 product units
    cfg: smart_insram::mac::VariantConfig,
    sampler: MismatchSampler,
    dots: u64,
    calls: u64,
}

impl<'a> VmmRunner<'a> {
    /// Run a list of dot products, each (weights[R'], codes[R']) with
    /// R' <= 16; returns integer dot-product estimates.
    fn dots(&mut self, jobs: &[(Vec<u8>, Vec<u8>)]) -> Result<Vec<u32>> {
        let rows = self.exe.rows();
        let batch = self.exe.batch();
        let mut out = Vec::with_capacity(jobs.len());
        for chunk in jobs.chunks(batch) {
            let mut db = smart_insram::runtime::DotBatch::nominal(
                batch,
                rows,
                self.cfg.v_bulk as f32,
                self.cfg.dac_mode.flag(),
                (self.cfg.t_sample / 4.0) as f32,
            );
            for (i, (ws, cs)) in chunk.iter().enumerate() {
                for r in 0..rows {
                    let (w, c) = if r < ws.len() { (ws[r], cs[r]) } else { (0, 0) };
                    let mc = self.sampler.sample();
                    db.set_row(i, r, w, c, mc.dvth.map(|x| x as f32), mc.dbeta.map(|x| x as f32));
                }
            }
            let res = self.exe.run(&db)?;
            self.calls += 1;
            for i in 0..chunk.len() {
                let units = f64::from(res.v_dot[i]) / self.ideal_fs * (rows as f64 * 225.0);
                out.push(units.round().max(0.0) as u32);
            }
            self.dots += chunk.len() as u64;
        }
        Ok(out)
    }

    fn classify_all(&mut self, mlp: &Mlp, data: &[(usize, [u8; N_IN])]) -> Result<Vec<usize>> {
        // pass 1: all layer-1 dots for all samples
        let jobs: Vec<(Vec<u8>, Vec<u8>)> = data
            .iter()
            .flat_map(|(_, x)| {
                (0..N_HID).map(move |h| {
                    ((0..N_IN).map(|i| mlp.w1[h][i]).collect(), x.to_vec())
                })
            })
            .collect();
        let acc1 = self.dots(&jobs)?;
        let hidden: Vec<[u8; N_HID]> = data
            .iter()
            .enumerate()
            .map(|(s, _)| {
                let mut hid = [0u8; N_HID];
                for h in 0..N_HID {
                    hid[h] = quant4(acc1[s * N_HID + h], 60);
                }
                hid
            })
            .collect();
        // pass 2: all layer-2 dots
        let jobs: Vec<(Vec<u8>, Vec<u8>)> = hidden
            .iter()
            .flat_map(|hid| {
                (0..N_OUT).map(move |o| {
                    ((0..N_HID).map(|h| mlp.w2[o][h]).collect(), hid.to_vec())
                })
            })
            .collect();
        let acc2 = self.dots(&jobs)?;
        Ok((0..data.len())
            .map(|s| {
                (0..N_OUT)
                    .max_by_key(|&o| acc2[s * N_OUT + o])
                    .unwrap()
            })
            .collect())
    }
}

fn main() -> Result<()> {
    let params = Params::default();
    let dir = default_artifact_dir();
    let mut rt = XlaRuntime::open(&dir)?;
    let exe = rt.mac_executable(BATCH)?;
    let mlp = Mlp::new(4);

    // dataset
    let mut rng = SplitMix64::new(11);
    let data: Vec<(usize, [u8; N_IN])> = (0..N_SAMPLES)
        .map(|k| {
            let class = k % N_OUT;
            (class, synth_input(&mut rng, class))
        })
        .collect();
    let exact_acc = data
        .iter()
        .filter(|(c, x)| exact_forward(&mlp, x) == *c)
        .count() as f64
        / data.len() as f64;
    println!(
        "exact 4-bit integer MLP accuracy: {:.1}% ({} samples)\n",
        exact_acc * 100.0,
        data.len()
    );

    let model = EnergyModel::default();
    println!(
        "{:<14} {:>9} {:>10} {:>12} {:>14} {:>12}",
        "variant", "accuracy", "vs-exact", "MACs", "MAC evals/s", "pJ/inference"
    );
    for variant in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let cfg = variant.config(&params);
        let native = NativeMacEngine::new(params, cfg);
        let mut runner = AnalogRunner {
            exe: &exe,
            ideal: IdealTransfer::calibrate(&native),
            cfg,
            sampler: MismatchSampler::new(7, params.circuit.sigma_vth, params.circuit.sigma_beta),
            macs: 0,
        };
        let t0 = std::time::Instant::now();
        let mut correct = 0usize;
        let mut agree = 0usize;
        for (class, x) in &data {
            let pred = runner.forward(&mlp, x)?;
            correct += usize::from(pred == *class);
            agree += usize::from(pred == exact_forward(&mlp, x));
        }
        let wall = t0.elapsed();
        let cost = nominal_cost(&params, variant, &model);
        let macs_per_inf = (N_HID * N_IN + N_OUT * N_HID) as f64;
        println!(
            "{:<14} {:>8.1}% {:>9.1}% {:>12} {:>14.0} {:>12.2}",
            variant.name(),
            correct as f64 / data.len() as f64 * 100.0,
            agree as f64 / data.len() as f64 * 100.0,
            runner.macs,
            runner.macs as f64 / wall.as_secs_f64(),
            cost.energy * macs_per_inf * 1e12,
        );
    }
    println!("\n(accuracy = class labels; vs-exact = agreement with integer math)");

    // ---- VMM mode: whole dot products on the 16-row array artifact -----
    let dot_exe = rt.dot_executable(16)?;
    println!("\n=== VMM mode (multi-row dot-product array, R = {}) ===", dot_exe.rows());
    println!(
        "{:<14} {:>9} {:>10} {:>8} {:>14}",
        "variant", "accuracy", "vs-exact", "calls", "dots/s"
    );
    for variant in [Variant::Smart, Variant::Aid] {
        let cfg = variant.config(&params);
        let native = smart_insram::mac::NativeDotEngine::new(params, cfg, dot_exe.rows());
        let mut runner = VmmRunner {
            exe: &dot_exe,
            ideal_fs: native.full_scale(),
            cfg,
            sampler: MismatchSampler::new(7, params.circuit.sigma_vth, params.circuit.sigma_beta),
            dots: 0,
            calls: 0,
        };
        let t0 = std::time::Instant::now();
        let preds = runner.classify_all(&mlp, &data)?;
        let wall = t0.elapsed();
        let correct = preds
            .iter()
            .zip(&data)
            .filter(|(p, (c, _))| *p == c)
            .count();
        let agree = preds
            .iter()
            .zip(&data)
            .filter(|(p, (_, x))| **p == exact_forward(&mlp, x))
            .count();
        println!(
            "{:<14} {:>8.1}% {:>9.1}% {:>8} {:>14.0}",
            variant.name(),
            correct as f64 / data.len() as f64 * 100.0,
            agree as f64 / data.len() as f64 * 100.0,
            runner.calls,
            runner.dots as f64 / wall.as_secs_f64(),
        );
    }
    println!("(one VMM dot replaces 16 scalar MACs: ~12x fewer executor calls)");
    Ok(())
}
