//! Quickstart: one 4x4-bit analog MAC through the full three-layer stack.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example quickstart
//! ```
//!
//! Loads the AOT-compiled HLO artifact (L2 jax model wrapping the L1
//! Pallas discharge kernel), executes it on the PJRT CPU client from
//! Rust (L3), and cross-checks the result against the native Rust
//! simulator — the library's core correctness contract.

use anyhow::Result;
use smart_insram::mac::{NativeMacEngine, Variant};
use smart_insram::montecarlo::McSample;
use smart_insram::params::Params;
use smart_insram::runtime::{default_artifact_dir, MacBatch, XlaRuntime};

fn main() -> Result<()> {
    let params = Params::default();
    let dir = default_artifact_dir();
    println!("artifacts: {}", dir.display());
    let mut rt = XlaRuntime::open(&dir)?;
    println!("PJRT platform: {}\n", rt.platform());

    let exe = rt.mac_executable(1)?;
    println!(
        "{:<14} {:>5} {:>12} {:>12} {:>10}",
        "variant", "a*b", "HLO (mV)", "native (mV)", "|delta|"
    );
    for variant in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let cfg = variant.config(&params);
        let native = NativeMacEngine::new(params, cfg);
        for (a, b) in [(15u8, 15u8), (13, 7), (5, 11)] {
            let mut batch = MacBatch::nominal(
                1,
                cfg.v_bulk as f32,
                cfg.dac_mode.flag(),
                cfg.t_sample as f32,
            );
            batch.set_row(0, a, b, [0.0; 4], [0.0; 4]);
            let out = exe.run(&batch)?;
            let want = native.mac(a, b, &McSample::nominal());
            let hlo_mv = f64::from(out.v_mult[0]) * 1e3;
            let nat_mv = want.v_mult * 1e3;
            println!(
                "{:<14} {a:>2}x{b:<2} {hlo_mv:>11.3} {nat_mv:>11.3} {:>9.4}",
                variant.name(),
                (hlo_mv - nat_mv).abs()
            );
            assert!((hlo_mv - nat_mv).abs() < 0.5, "layers disagree!");
        }
    }

    println!("\nall HLO outputs match the native oracle — stack is healthy");
    Ok(())
}
