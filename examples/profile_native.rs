//! Perf tool: micro-profiles the native-oracle hot path and decomposes a
//! campaign into batcher vs execution cost. Used for the EXPERIMENTS.md
//! §Perf iteration log.
use smart_insram::mac::{NativeMacEngine, Variant};
use smart_insram::montecarlo::{McSample, MismatchSampler};
use smart_insram::params::Params;
use std::time::Instant;

fn main() {
    let p = Params::default();
    let e = NativeMacEngine::new(p, Variant::Smart.config(&p));
    let mc = McSample::nominal();
    let t0 = Instant::now();
    let mut acc = 0.0;
    for _ in 0..10_000 {
        acc += e.mac(15, 15, &mc).v_mult;
    }
    let us_per_eval = t0.elapsed().as_secs_f64() / 10_000.0 * 1e6;
    println!("mac(15,15): {us_per_eval:.2} us/eval (sum {acc:.1})");

    let mut s = MismatchSampler::new(1, 8e-3, 0.02);
    let t0 = Instant::now();
    let mut n = 0.0;
    for _ in 0..100_000 {
        n += s.sample().dvth[0];
    }
    println!("sampler: {:.3} us/sample (sum {n:.3})", t0.elapsed().as_secs_f64() / 100_000.0 * 1e6);

    // campaign decomposition
    use smart_insram::coordinator::{Batcher, CampaignSpec};
    let spec = CampaignSpec::paper_fig8(Variant::Smart);
    let cfg = Variant::Smart.config(&p);
    let mk_batcher = || Batcher::new(
        vec![(15u8, 15u8)], 1000, 256, (&cfg).into(),
        MismatchSampler::new(2022, p.circuit.sigma_vth, p.circuit.sigma_beta),
    );
    let t0 = Instant::now();
    let batches: Vec<_> = mk_batcher().collect();
    println!("batcher: {:.2} ms for {} batches", t0.elapsed().as_secs_f64()*1e3, batches.len());
    let t0 = Instant::now();
    let mut outs = Vec::new();
    for b in &batches {
        outs.push(smart_insram::coordinator::run_native_batch(&e, b));
    }
    println!("native exec: {:.2} ms", t0.elapsed().as_secs_f64()*1e3);

    // block engine decomposition: the same 1000 items through one reusable
    // 256-lane SoA block (DESIGN.md §9)
    use smart_insram::mac::{BlockKernel, SimKernel, TrialBlock};
    let block_sampler = MismatchSampler::new(2022, p.circuit.sigma_vth, p.circuit.sigma_beta);
    let mut blk = TrialBlock::with_capacity(256);
    let t0 = Instant::now();
    let mut n_blocks = 0u32;
    let mut cursor = 0u64;
    while cursor < 1000 {
        let n = 256usize.min((1000 - cursor) as usize);
        blk.reset(n);
        let (dvth, dbeta) = blk.deviates_mut();
        block_sampler.fill_block(cursor, dvth, dbeta);
        for i in 0..n {
            blk.set_operands(i, 15, 15);
        }
        BlockKernel.simulate(&e, &mut blk);
        n_blocks += 1;
        cursor += n as u64;
    }
    println!(
        "block exec:  {:.2} ms for {n_blocks} blocks (reused SoA buffers)",
        t0.elapsed().as_secs_f64() * 1e3
    );
    let _ = (outs, spec);
}
