//! End-to-end driver: the paper's headline Monte-Carlo experiment
//! (Fig. 8 + Fig. 9 + the accuracy column of Table 1), on the real
//! AOT/PJRT path with the multi-worker coordinator.
//!
//! ```bash
//! make artifacts && cargo run --offline --release --example mc_sweep
//! ```
//!
//! Runs a 1000-point MC (process + mismatch) of the 1111 x 1111 MAC for
//! every design variant, prints the V_multiplication histograms, and the
//! full-operand-space accuracy sweep that feeds Table 1. The run is
//! recorded in EXPERIMENTS.md.

use anyhow::Result;
use smart_insram::coordinator::{run_campaign, Backend, CampaignSpec, Workload};
use smart_insram::mac::Variant;
use smart_insram::params::Params;
use smart_insram::report;
use smart_insram::runtime::default_artifact_dir;

fn main() -> Result<()> {
    let params = Params::default();
    let dir = default_artifact_dir();
    let n_mc = std::env::args()
        .nth(1)
        .map(|s| s.parse().expect("n_mc"))
        .unwrap_or(1000u32);

    println!("=== Fig. 8/9 — {n_mc}-point Monte-Carlo, 1111 x 1111 ===\n");
    // One persistent engine: the PJRT executable compiles once and serves
    // every campaign below (§Perf: compile dominates cold campaigns).
    let mut engine = smart_insram::coordinator::CampaignEngine::new(dir.clone(), 256, 1)?;
    let mut rows = Vec::new();
    for variant in [Variant::Aid, Variant::Smart, Variant::Imac, Variant::SmartOnImac] {
        let mut spec = CampaignSpec::paper_fig8(variant);
        spec.n_mc = n_mc;
        let r = engine.run(&params, &spec)?;
        print!("{}", report::mc_panel(variant.name(), &r));
        println!(
            "   throughput {:.0} evals/s  wall {:.2?}\n",
            r.throughput(),
            r.wall
        );
        rows.push((variant, r));
    }

    println!("=== normalized sigma at max code (paper: SMART 0.009 << AID 0.086 << IMAC 0.6) ===");
    for (v, r) in &rows {
        println!(
            "  {:<14} sigma/FS = {:.4}   fault rate = {:.4}",
            v.name(),
            r.raw_vmult.std_dev() / r.full_scale,
            r.accuracy.fault_rate
        );
    }
    let sigma = |v: Variant| {
        rows.iter()
            .find(|(x, _)| *x == v)
            .map(|(_, r)| r.raw_vmult.std_dev() / r.full_scale)
            .unwrap()
    };
    assert!(
        sigma(Variant::Smart) < sigma(Variant::Aid),
        "SMART must beat AID"
    );

    println!("\n=== full 16x16 operand space (Table 1 accuracy metric) ===");
    let mut sigmas = Vec::new();
    for variant in [Variant::Smart, Variant::Aid, Variant::Imac] {
        let spec = CampaignSpec {
            variant,
            workload: Workload::FullSweep,
            n_mc: (n_mc / 4).max(8),
            seed: 2022,
            corner: smart_insram::montecarlo::Corner::Tt,
            workers: 1,
            batch: 256,
            shards: 0,
            block: 0,
            kernel: smart_insram::mac::KernelKind::Block,
        };
        let r = engine.run(&params, &spec)?;
        println!(
            "  {:<14} rms/FS = {:.4}  sigma/FS = {:.4}  BER = {:.4}  ({} evals, {:.2?})",
            variant.name(),
            r.accuracy.rms_norm,
            r.accuracy.sigma_norm,
            r.accuracy.ber,
            r.rows,
            r.wall
        );
        sigmas.push((variant, r.accuracy.rms_norm));
    }

    println!("\n=== Table 1 ===");
    println!(
        "{}",
        report::build_table1(&params, &sigmas, &smart_insram::energy::EnergyModel::default())
    );
    Ok(())
}
